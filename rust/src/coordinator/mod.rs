//! The global controller (paper §3, §4.3, Fig. 4).
//!
//! The controller owns every scalar (alpha, beta, rz, rr) and decides
//! termination on the fly — the capability fixed FPGA designs lack
//! (§2.3.1).  Since the program-layer refactor it no longer hand-rolls
//! per-phase calls: it compiles one [`Program`](crate::program::Program)
//! up front and pushes every trip through the
//! [`InstructionBus`](crate::program::InstructionBus), which routes
//! Type-II instructions to the computation modules and Type-I/III to
//! the vector-control + memory modules, with scalar results (pap, rz,
//! rr) and `MemResponse` write acks flowing back.  The same compiled
//! instructions drive the time plane (`Dataflow::from_program`), so the
//! two planes cannot drift.
//!
//! Fig. 4's two controller optimizations are reproduced as compiled
//! trips:
//! 1. the merged init (the `rp = -1` trip performs Alg. 1 lines 1–5 on
//!    the steady-state modules with alpha = 1, beta = 0 pre-bound), and
//! 2. M8 (dot rr) hoisted before M5–M7, so a converged iteration
//!    dispatches the converged-exit trip: M3 alone finishes x.
//!
//! Value-plane backends implement
//! [`InstDispatch`](crate::program::InstDispatch): [`NativeExecutor`]
//! interprets the Type-II batch instruction by instruction against the
//! module implementations, while any [`PhaseExecutor`] (the PJRT
//! artifact runtime) is adapted automatically at phase granularity.

use std::sync::Arc;

use crate::engine::pool::{self, WorkerPool};
use crate::hbm::ChannelMode;
use crate::isa::InstTrace;
use crate::obs::catalog as obs;
use crate::precision::adaptive::{PrecisionController, PrecisionMode, PrecisionTrace};
use crate::precision::{stats, Scheme};
use crate::program::{
    bucket_ceiling, DispatchReturn, HbmMemoryMap, InstDispatch, LaneSlice, Program, ProgramCache,
    Scalars, ScalarRole, VectorFile,
};
use crate::solver::ResidualTrace;
use crate::sparse::CsrMatrix;
use crate::vsr::Phase;

/// The three per-iteration phase computations + the init pass, at phase
/// granularity.  This is the artifact-runtime interface (PJRT executes
/// whole-phase HLO programs); any implementor doubles as an
/// [`InstDispatch`] backend via the blanket impl in `program::bus`.
/// All vectors FP64 (§6); the scheme only affects the executor's SpMV.
pub trait PhaseExecutor {
    /// Lines 1-5: returns (r, z, p, rz, rr) from x0 and b.
    fn init(&mut self, x0: &[f64], b: &[f64]) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64, f64);
    /// Phase-1: (ap, pap) from p.
    fn phase1(&mut self, p: &[f64]) -> (Vec<f64>, f64);
    /// Phase-2: (r', rz_new, rr) from r, ap, alpha.
    fn phase2(&mut self, r: &[f64], ap: &[f64], alpha: f64) -> (Vec<f64>, f64, f64);
    /// Phase-3: (p', x') from r, p, x, alpha, beta (z recomputed inside).
    fn phase3(
        &mut self,
        r: &[f64],
        p: &[f64],
        x: &[f64],
        alpha: f64,
        beta: f64,
    ) -> (Vec<f64>, Vec<f64>);
    /// M3 alone (converged-exit path): x' = x + alpha p.
    fn update_x_only(&mut self, p: &[f64], x: &[f64], alpha: f64) -> Vec<f64>;
}

/// How the batched solve dispatches its block-CG data plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BlockMode {
    /// Every trip's data ops run per lane (the PR 5 dispatch): L nnz
    /// passes and L vector sweeps per batched iteration.
    #[default]
    PerLane,
    /// The PR 6 staging path: one [`InstDispatch::batch_spmv`] pass per
    /// iteration feeds every live lane, but the lane-major block is
    /// re-materialized around it — an O(n·L) gather of the inputs plus
    /// an O(n·L) scatter of the outputs per pass (`2·n·L` element moves
    /// per iteration on [`crate::precision::stats::vector_element_moves`]) —
    /// and the M2–M8 vector sweeps still run per lane.  Kept reachable
    /// as the measured baseline the resident layout is paired against.
    Staged,
    /// The resident layout: `x/p/r/ap/z` live in interleaved lane-major
    /// arenas from program issue to converged exit.  The batch SpMV
    /// reads `p` and writes staged `ap` in place (no gather, no
    /// scatter, no per-pass allocation), the M2–M8 vector trips run
    /// batch-wide through the [`InstDispatch`] block vector ops, and
    /// commits are whole-arena swaps — steady-state iterations perform
    /// **zero** block-boundary element moves.  Per-lane instruction
    /// streams, traces, and acks are issued exactly as before
    /// ([`crate::program::InstructionBus::issue_lane`]), and every
    /// result bit matches the per-lane walk.  Backends that cannot
    /// serve the block protocol degrade gracefully: no block vector ops
    /// → the staged path; `batch_spmv` declines → per-lane; a mid-solve
    /// decline or a single surviving lane → the lanes gather out into
    /// per-lane [`VectorFile`]s and finish on the per-lane walk.
    Resident,
}

/// Controller configuration.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    /// Convergence threshold tau on rr = |r|^2.
    pub tol: f64,
    /// Iteration cap per right-hand side.
    pub max_iters: u32,
    /// Record rr per iteration (Fig. 9 traces).
    pub record_trace: bool,
    /// Record every issued instruction (tests / time plane).
    pub record_instructions: bool,
    /// Channel policy baked into the compiled memory map (§5.7).
    pub channel_mode: ChannelMode,
    /// Lanes dispatched concurrently per trip by
    /// [`Coordinator::solve_batch_parallel`] (the sequential
    /// [`Coordinator::solve_batch`] ignores it).  `0` resolves to the
    /// machine default via
    /// [`pool::default_lane_workers`](crate::engine::pool::default_lane_workers),
    /// which honors the `CALLIPEPLA_LANE_WORKERS` environment override.
    pub lane_workers: usize,
    /// Extra bound on the lanes a compiled chunk carries (`0` = none:
    /// chunks are sized by [`HbmMemoryMap::max_batch`] alone).  Lets
    /// scheduling studies — and the chunk-seam tests — exercise the
    /// batch-splitting path at small `n`; results are chunk-invariant
    /// either way (lanes are independent).
    pub max_chunk_lanes: u32,
    /// Block-CG dispatch mode for the batched solve paths (see
    /// [`BlockMode`]).  Per-lane scalars, trip barriers, the
    /// instruction streams, and every result bit are identical across
    /// all three modes; only data movement differs.  Single-lane
    /// batches always run per-lane dispatch — there is no block to
    /// amortize over, so staging or residency would only add moves.
    pub block: BlockMode,
    /// Precision governance (PR 8).  `Static` leaves the backend's own
    /// scheme untouched — the coordinator never calls
    /// [`InstDispatch::bind_scheme`], so static solves are bit for bit
    /// the pre-adaptive controller.  `Adaptive` starts every lane on
    /// the policy's start scheme and escalates lanes *independently*
    /// from their own residual histories, re-binding the executor
    /// before each SpMV pass; the decision sequence is a pure function
    /// of each lane's rr sequence, so all dispatch paths emit the same
    /// [`PrecisionTrace`].
    pub precision: PrecisionMode,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            tol: 1e-12,
            max_iters: 20_000,
            record_trace: false,
            record_instructions: false,
            channel_mode: ChannelMode::Double,
            lane_workers: 0,
            max_chunk_lanes: 0,
            block: BlockMode::PerLane,
            precision: PrecisionMode::default(),
        }
    }
}

/// Outcome of a coordinated solve.
#[derive(Debug)]
pub struct CoordResult {
    /// The solution iterate.
    pub x: Vec<f64>,
    /// Main-loop iterations executed.
    pub iters: u32,
    /// Whether rr reached the threshold.
    pub converged: bool,
    /// Final rr = |r|^2.
    pub final_rr: f64,
    /// rr per iteration, if recorded.
    pub trace: ResidualTrace,
    /// Every instruction issued for this system, if recorded.
    pub instructions: InstTrace,
    /// Type-III write acknowledgements received (§4.2).
    pub mem_acks: usize,
    /// The precision schedule that produced `x` (PR 8): which scheme
    /// governed each SpMV pass and why.  Static solves carry the single
    /// pinned scheme; an adaptive schedule can be replayed bitwise with
    /// [`PrecisionController::replay`].
    pub precision: PrecisionTrace,
}

/// The global controller.
pub struct Coordinator {
    /// Controller configuration.
    pub cfg: CoordinatorConfig,
    /// Shared compiled-program memo; `None` compiles per solve (the
    /// pre-cache behavior, still what one-shot CLI solves use).
    cache: Option<Arc<ProgramCache>>,
}

impl Coordinator {
    /// A controller with the given configuration, compiling its program
    /// fresh per solve.
    pub fn new(cfg: CoordinatorConfig) -> Self {
        Self { cfg, cache: None }
    }

    /// A controller that draws its compiled programs from a shared
    /// [`ProgramCache`]: solves are executed through the *bucket*
    /// program ([`bucket_ceiling`]-sized memory map, actual-`n` vectors
    /// rebased into it) so repeated solves for the same (bucket, mode,
    /// lane-bucket) key never recompile.  Results are bitwise identical
    /// to a fresh-compile [`Coordinator::new`] controller's (pinned in
    /// `tests/service.rs`).
    pub fn with_cache(cfg: CoordinatorConfig, cache: Arc<ProgramCache>) -> Self {
        Self { cfg, cache: Some(cache) }
    }

    /// The length the compiled program is (or would be) built at for an
    /// `n`-element system: the bucket ceiling when caching, exact `n`
    /// when compiling fresh.
    fn compile_n(&self, n: u32) -> u32 {
        if self.cache.is_some() {
            bucket_ceiling(n)
        } else {
            n
        }
    }

    /// Run the Fig. 4 controller program to completion: compile once,
    /// then dispatch trips through the instruction bus, binding alpha /
    /// beta on the fly and deciding termination from the returned
    /// scalars.  Every solve is a batch of one: this is the lane-count-1
    /// case of [`Coordinator::solve_batch`], so the batched program is
    /// the one execution path.
    pub fn solve<D: InstDispatch>(&mut self, exec: &mut D, b: &[f64], x0: &[f64]) -> CoordResult {
        self.solve_batch(exec, &[b], Some(&[x0])).pop().expect("one lane in, one result out")
    }

    /// Solve many right-hand sides through **one compiled instruction
    /// stream**: the trips are vectorized over the batch lanes
    /// (trip-major, lane-minor issue order), each lane's scalar slots
    /// (alpha, beta, rz, rr) are bound at issue time, and a lane whose
    /// hoisted M8 reports rr <= tau dispatches its converged-exit trip
    /// and stops issuing — individual systems terminate on the fly
    /// (the paper's §2.3.1 capability, at batch granularity) without
    /// stalling or perturbing the rest of the batch.
    ///
    /// `x0` supplies per-lane starts (`None` = all zeros).  Batches
    /// larger than [`HbmMemoryMap::max_batch`] lanes are transparently
    /// processed in channel-window-sized chunks.  Results come back in
    /// input order, each bitwise identical to a lone
    /// [`Coordinator::solve`] on the same system.
    ///
    /// ```
    /// use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
    /// use callipepla::precision::Scheme;
    /// use callipepla::sparse::synth;
    ///
    /// let a = synth::laplace2d_shifted(100, 0.2);
    /// let mut coord = Coordinator::new(CoordinatorConfig::default());
    /// let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
    /// let b0 = vec![1.0; a.n];
    /// let b1 = vec![2.0; a.n];
    /// let results = coord.solve_batch(&mut exec, &[b0.as_slice(), b1.as_slice()], None);
    /// assert!(results.iter().all(|r| r.converged));
    /// ```
    pub fn solve_batch<D: InstDispatch>(
        &mut self,
        exec: &mut D,
        rhs: &[&[f64]],
        x0: Option<&[&[f64]]>,
    ) -> Vec<CoordResult> {
        if rhs.is_empty() {
            return Vec::new();
        }
        check_batch_shapes(rhs, x0);
        let n = rhs[0].len();
        // Only materialized when lanes actually start from zero.
        let zeros = if x0.is_none() { vec![0.0; n] } else { Vec::new() };
        let cap = self.chunk_cap(n as u32);
        // Chunk walk: keep in lockstep with solve_batch_parallel's.
        let mut out = Vec::with_capacity(rhs.len());
        let mut start = 0;
        while start < rhs.len() {
            let end = (start + cap).min(rhs.len());
            let x0_chunk = x0_for_chunk(x0, &zeros, start..end);
            out.extend(self.solve_chunk(exec, &rhs[start..end], &x0_chunk));
            start = end;
        }
        out
    }

    /// [`Coordinator::solve_batch`] with **lane-parallel dispatch**:
    /// each trip's per-lane instruction streams are fanned out across
    /// up to [`CoordinatorConfig::lane_workers`] workers of the
    /// process-wide pool, one lane's [`LaneSlice`] (bus + vector file)
    /// and executor per worker, with a barrier at every trip boundary —
    /// the Fig. 4 trip-major schedule and the per-lane converged exit
    /// are unchanged, only *who* walks the lanes differs.
    ///
    /// Because the lanes share nothing mutable (each has its own
    /// executor in `execs`, one per right-hand side), the results are
    /// **bitwise identical** to the sequential [`Coordinator::solve_batch`]
    /// walk at every worker count — a scheduling refactor, not a
    /// rounding change (pinned in `tests/lane_parallel.rs`).
    ///
    /// ```
    /// use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
    /// use callipepla::precision::Scheme;
    /// use callipepla::sparse::synth;
    ///
    /// let a = synth::laplace2d_shifted(100, 0.2);
    /// let mut coord = Coordinator::new(CoordinatorConfig::default());
    /// let mut execs: Vec<_> =
    ///     (0..2).map(|_| NativeExecutor::with_threads(&a, Scheme::MixV3, 1)).collect();
    /// let b0 = vec![1.0; a.n];
    /// let b1 = vec![2.0; a.n];
    /// let results = coord.solve_batch_parallel(&mut execs, &[b0.as_slice(), b1.as_slice()], None);
    /// assert!(results.iter().all(|r| r.converged));
    /// ```
    pub fn solve_batch_parallel<D: InstDispatch + Send>(
        &mut self,
        execs: &mut [D],
        rhs: &[&[f64]],
        x0: Option<&[&[f64]]>,
    ) -> Vec<CoordResult> {
        assert_eq!(execs.len(), rhs.len(), "one executor per batch lane");
        if rhs.is_empty() {
            return Vec::new();
        }
        check_batch_shapes(rhs, x0);
        let n = rhs[0].len();
        let zeros = if x0.is_none() { vec![0.0; n] } else { Vec::new() };
        let cap = self.chunk_cap(n as u32);
        // Chunk walk: keep in lockstep with solve_batch's.
        let mut out = Vec::with_capacity(rhs.len());
        let mut start = 0;
        while start < rhs.len() {
            let end = (start + cap).min(rhs.len());
            let x0_chunk = x0_for_chunk(x0, &zeros, start..end);
            let chunk =
                self.solve_chunk_parallel(&mut execs[start..end], &rhs[start..end], &x0_chunk);
            out.extend(chunk);
            start = end;
        }
        out
    }

    /// Lanes per compiled chunk: the channel-window bound, optionally
    /// tightened by [`CoordinatorConfig::max_chunk_lanes`].  A window
    /// bound of 0 means even one lane outgrows a channel window; let
    /// the single-lane compile raise the precise per-vector panic (same
    /// behavior as the pre-batch memory map).  Under a cache the lanes
    /// are laid out at the *bucket* stride, so the window caps fewer of
    /// them.
    fn chunk_cap(&self, n: u32) -> usize {
        let window = (HbmMemoryMap::max_batch(self.compile_n(n)) as usize).max(1);
        match self.cfg.max_chunk_lanes {
            0 => window,
            cap => window.min(cap as usize),
        }
    }

    /// The compiled program a chunk of `lanes` lanes executes: the
    /// cached bucket program (ceiling-sized map, possibly more compiled
    /// lanes than live ones — extra lanes are just unused address
    /// windows) or a fresh exact-shape compile.  The interpreter
    /// executes the actual `n`-element vectors either way, so the
    /// numerics are identical.
    fn chunk_program(&mut self, n: u32, lanes: u32) -> Arc<Program> {
        match &self.cache {
            Some(cache) => cache.get_batched(n, self.cfg.channel_mode, lanes),
            None => Arc::new(Program::compile_batched(n, self.cfg.channel_mode, lanes)),
        }
    }

    /// Fresh per-lane controller states for one chunk.  `scheme_of`
    /// names lane `k`'s executor's built-in scheme — the scheme a
    /// static-mode lane pins (so nothing is ever re-bound).
    fn make_lanes(
        &self,
        program: &Program,
        rhs: &[&[f64]],
        x0: &[&[f64]],
        scheme_of: impl Fn(usize) -> Scheme,
    ) -> Vec<LaneState> {
        let mut lanes = Vec::with_capacity(rhs.len());
        for (k, (b, xs)) in rhs.iter().zip(x0).enumerate() {
            let ctrl = PrecisionController::for_mode(self.cfg.precision, scheme_of(k), self.cfg.tol);
            lanes.push(LaneState::new(b, xs, program.lane_offset_beats(k as u32), &self.cfg, ctrl));
        }
        lanes
    }

    /// One channel-window-sized chunk of [`Coordinator::solve_batch`]:
    /// compile the batched program, then walk the Fig. 4 controller
    /// schedule trip-major across the live lanes — lane-minor within
    /// each trip, on the calling thread.  This sequential walk is the
    /// oracle the lane-parallel path is bitwise-pinned against.
    fn solve_chunk<D: InstDispatch>(
        &mut self,
        exec: &mut D,
        rhs: &[&[f64]],
        x0: &[&[f64]],
    ) -> Vec<CoordResult> {
        let program = self.chunk_program(rhs[0].len() as u32, rhs.len() as u32);
        let cfg = self.cfg;
        // Resident mode: the whole chunk runs on lane-major arenas when
        // the backend implements the block vector-op family.  A `Some`
        // return carries the chunk's lanes — all retired, or gathered
        // out mid-solve into per-lane vector files — and any survivors
        // finish on the per-lane walk below.  `None` means the first
        // batch SpMV declined before anything was issued: restart the
        // chunk per-lane from scratch (the staged path would need the
        // same batch kernel, so there is nothing to degrade to).
        let mut tried_resident = false;
        if cfg.block == BlockMode::Resident && rhs.len() > 1 && exec.block_vector_ops() {
            tried_resident = true;
            obs::COORD_BLOCK_RESIDENT_CHUNKS.inc();
            if let Some(mut lanes) = solve_chunk_resident(&cfg, &program, exec, rhs, x0) {
                run_lane_loop(&cfg, &program, &mut lanes, exec, false);
                return lanes.into_iter().map(LaneState::into_result).collect();
            }
        }
        let fallback = exec.active_scheme();
        let mut lanes = self.make_lanes(&program, rhs, x0, |_| fallback);
        // Staged block-CG mode: one batch_spmv ahead of each SpMV trip
        // round stages every live lane's ap, so the M1s below consume
        // one shared matrix pass.  A backend that declines (first call
        // returns false) drops the mode for the whole chunk.  A
        // resident request degrades to this path when the backend lacks
        // the block vector ops (its batch kernel may still serve).
        let mut block = match cfg.block {
            BlockMode::PerLane => false,
            BlockMode::Staged => true,
            BlockMode::Resident => !tried_resident,
        };
        if block && !tried_resident && cfg.block == BlockMode::Resident && rhs.len() > 1 {
            // Resident was requested but the backend lacks the block
            // vector ops: first rung of the degrade ladder (its batch
            // SpMV may still serve the staged pass).
            obs::COORD_BLOCK_DEGRADE_STAGED.inc();
        }
        if block {
            block = block_spmv_pass(&mut lanes, exec, true, false);
        }
        for lane in lanes.iter_mut() {
            lane_init(&cfg, &program, lane, exec);
        }
        run_lane_loop(&cfg, &program, &mut lanes, exec, block);
        lanes.into_iter().map(LaneState::into_result).collect()
    }

    /// One chunk of [`Coordinator::solve_batch_parallel`]: the same
    /// trip-major schedule as [`Coordinator::solve_chunk`], with every
    /// trip's live lanes fanned out across the pool and a barrier
    /// before the next trip starts.
    fn solve_chunk_parallel<D: InstDispatch + Send>(
        &mut self,
        execs: &mut [D],
        rhs: &[&[f64]],
        x0: &[&[f64]],
    ) -> Vec<CoordResult> {
        let program = self.chunk_program(rhs[0].len() as u32, rhs.len() as u32);
        let cfg = self.cfg;
        let workers =
            if cfg.lane_workers == 0 { pool::default_lane_workers() } else { cfg.lane_workers };
        // The caller participates in every fan-out, so a budget of `w`
        // workers is the caller plus w - 1 pool helpers.
        let helpers = workers.saturating_sub(1);
        let pool = pool::global();
        // Resident mode runs the batch-wide rounds on the first lane's
        // executor (every executor serves the same matrix); its block
        // kernels parallelize internally over row ranges / dot lanes,
        // so the per-trip lane fan-out only resumes for lanes that
        // gather out.  Same return protocol as the sequential path.
        let mut tried_resident = false;
        if cfg.block == BlockMode::Resident
            && rhs.len() > 1
            && !execs.is_empty()
            && execs[0].block_vector_ops()
        {
            tried_resident = true;
            obs::COORD_BLOCK_RESIDENT_CHUNKS.inc();
            if let Some(mut lanes) = solve_chunk_resident(&cfg, &program, &mut execs[0], rhs, x0) {
                run_lane_loop_parallel(pool, helpers, &cfg, &program, &mut lanes, execs, false);
                return lanes.into_iter().map(LaneState::into_result).collect();
            }
        }
        let schemes: Vec<Scheme> = execs.iter().map(|e| e.active_scheme()).collect();
        let mut lanes = self.make_lanes(&program, rhs, x0, |k| schemes[k]);
        // Staged block-CG mode: the batch-wide SpMV runs on the first
        // lane's executor between the trip barriers, before the lanes
        // fan out; the staged-ap handshake then makes each fanned M1 a
        // consume, not a stream.
        let mut block = !execs.is_empty()
            && match cfg.block {
                BlockMode::PerLane => false,
                BlockMode::Staged => true,
                BlockMode::Resident => !tried_resident,
            };
        if block && !tried_resident && cfg.block == BlockMode::Resident && rhs.len() > 1 {
            // Same first rung of the degrade ladder as the sequential
            // chunk walk.
            obs::COORD_BLOCK_DEGRADE_STAGED.inc();
        }
        if block {
            block = block_spmv_pass(&mut lanes, &mut execs[0], true, false);
        }
        fan_trips(pool, helpers, &mut lanes, execs, false, |l, e| lane_init(&cfg, &program, l, e));
        run_lane_loop_parallel(pool, helpers, &cfg, &program, &mut lanes, execs, block);
        lanes.into_iter().map(LaneState::into_result).collect()
    }
}

// --------------------------------------------------------------------
// Per-lane controller state and the trip steps both dispatch paths
// share.  Each function touches exactly one lane's state and executor,
// which is the whole lane-parallel safety argument: nothing here can
// contend, so fanning lanes across workers cannot change a bit.
// --------------------------------------------------------------------

/// Per-lane controller state: the lane's dispatch slice (bus + vector
/// file + beat offset) plus its scalar slots and liveness.
struct LaneState {
    slice: LaneSlice,
    trace: ResidualTrace,
    /// The lane's precision governor (PR 8): names the scheme every
    /// issued Type-I word carries and — in adaptive mode — decides when
    /// the lane escalates.  Lanes escalate independently.
    ctrl: PrecisionController,
    rz: f64,
    rr: f64,
    /// Step length bound for the lane's current iteration (line 8).
    alpha: f64,
    /// M6's r.z of the current iteration (feeds beta, then becomes rz).
    rz_new: f64,
    iters: u32,
    converged: bool,
    /// Still issuing trips; a converged or iteration-capped lane's slot
    /// is freed and never issues again.
    live: bool,
}

impl LaneState {
    fn new(
        b: &[f64],
        x0: &[f64],
        offset_beats: u32,
        cfg: &CoordinatorConfig,
        ctrl: PrecisionController,
    ) -> Self {
        Self::with_slice(LaneSlice::new(b, x0, offset_beats, cfg.record_instructions), cfg, ctrl)
    }

    /// A lane whose vectors live in the coordinator's resident arenas:
    /// the [`VectorFile`] starts empty and is materialized only on
    /// gather-out or converged exit.
    fn new_resident(offset_beats: u32, cfg: &CoordinatorConfig, ctrl: PrecisionController) -> Self {
        Self::with_slice(LaneSlice::new_resident(offset_beats, cfg.record_instructions), cfg, ctrl)
    }

    fn with_slice(slice: LaneSlice, cfg: &CoordinatorConfig, ctrl: PrecisionController) -> Self {
        Self {
            slice,
            trace: ResidualTrace::new(cfg.record_trace),
            ctrl,
            rz: 0.0,
            rr: 0.0,
            alpha: 0.0,
            rz_new: 0.0,
            iters: 0,
            converged: false,
            live: true,
        }
    }

    /// The lane's issue-time scalars: alpha and beta as given, plus the
    /// controller's current scheme as the third bound-at-issue scalar
    /// (stamped into every Type-I word of the trip).
    fn scalars(&self, alpha: f64, beta: f64) -> Scalars {
        Scalars { alpha, beta, scheme: self.ctrl.current() }
    }

    fn into_result(mut self) -> CoordResult {
        CoordResult {
            x: std::mem::take(&mut self.slice.mem.x),
            iters: self.iters,
            converged: self.converged,
            final_rr: self.rr,
            trace: self.trace,
            instructions: self.slice.bus.take_trace(),
            mem_acks: self.slice.bus.acks().len(),
            precision: self.ctrl.into_trace(),
        }
    }
}

/// Scalar a trip returned, or a fail-fast panic on a shape bug.
fn ret_scalar(ret: &DispatchReturn, role: ScalarRole) -> f64 {
    match role {
        ScalarRole::Pap => ret.pap,
        ScalarRole::Rz => ret.rz,
        ScalarRole::Rr => ret.rr,
    }
    .unwrap_or_else(|| panic!("backend did not return {role:?}"))
}

/// Merged init for one lane, alpha = 1 / beta = 0 pre-bound (Fig. 4,
/// rp = -1).
fn lane_init<D: InstDispatch>(
    cfg: &CoordinatorConfig,
    program: &Program,
    lane: &mut LaneState,
    exec: &mut D,
) {
    bind_lane_scheme(lane, exec);
    let scalars = lane.scalars(1.0, 0.0);
    let ret = lane.slice.trip(&program.init, scalars, exec);
    let rz = ret_scalar(&ret, ScalarRole::Rz);
    let rr = ret_scalar(&ret, ScalarRole::Rr);
    note_init(cfg, lane, rz, rr);
}

/// Re-bind the executor's decode width to the lane's current scheme
/// ahead of a trip that may stream the matrix.  Static lanes skip the
/// call entirely — the backend's built-in scheme is already the lane's
/// pinned scheme, and never touching [`InstDispatch::bind_scheme`]
/// keeps static solves bit for bit the pre-adaptive controller.
fn bind_lane_scheme<D: InstDispatch>(lane: &LaneState, exec: &mut D) {
    if lane.ctrl.is_adaptive() {
        exec.bind_scheme(lane.ctrl.current());
    }
}

/// Post-init scalar bookkeeping, shared between the per-lane trip path
/// and the resident batch-wide rounds (which compute rz / rr with the
/// block kernels but must track liveness identically).
fn note_init(cfg: &CoordinatorConfig, lane: &mut LaneState, rz: f64, rr: f64) {
    obs::COORD_TRIPS_INIT.inc();
    lane.rz = rz;
    lane.rr = rr;
    lane.trace.push(lane.rr);
    lane.converged = lane.rr <= cfg.tol;
    lane.live = !lane.converged && cfg.max_iters > 0;
    if lane.converged {
        obs::COORD_LANES_CONVERGED.inc();
    } else if !lane.live {
        obs::COORD_LANES_CAPPED.inc();
    }
    // The controller observes a pass's rr only when the solve goes on
    // to another pass — the same hook point as the reference solver's,
    // so traces cannot drift between the two (tests/adaptive_precision.rs).
    if lane.live {
        lane.ctrl.observe(lane.rr);
    }
}

/// Post-exit-trip bookkeeping (shared with the resident rounds).
fn note_exit(lane: &mut LaneState) {
    obs::COORD_TRIPS_EXIT.inc();
    obs::COORD_LANES_CONVERGED.inc();
    lane.iters += 1;
    lane.trace.push(lane.rr);
    lane.converged = true;
    lane.live = false;
}

/// Post-phase-3 bookkeeping (shared with the resident rounds).
fn note_phase3(cfg: &CoordinatorConfig, lane: &mut LaneState) {
    obs::COORD_TRIPS_PHASE3.inc();
    lane.rz = lane.rz_new;
    lane.iters += 1;
    lane.trace.push(lane.rr);
    if lane.iters >= cfg.max_iters {
        lane.live = false;
        obs::COORD_LANES_CAPPED.inc();
    }
    // Same observe gate as note_init: the final rr of a capped (or
    // converged — note_exit never observes) solve is not observed.
    if lane.live {
        lane.ctrl.observe(lane.rr);
    }
}

/// Phase-1 trip for one lane -> its pap -> its alpha (scalar unit,
/// line 8).
fn lane_phase1<D: InstDispatch>(program: &Program, lane: &mut LaneState, exec: &mut D) {
    obs::COORD_TRIPS_PHASE1.inc();
    bind_lane_scheme(lane, exec);
    let scalars = lane.scalars(0.0, 0.0);
    let r1 = lane.slice.trip(program.phase(Phase::Phase1), scalars, exec);
    lane.alpha = lane.rz / ret_scalar(&r1, ScalarRole::Pap);
}

/// Phase-2 trip for one lane (its hoisted M8 rr is checked by the
/// following trip step: Fig. 4 opt 2, per RHS).
fn lane_phase2<D: InstDispatch>(program: &Program, lane: &mut LaneState, exec: &mut D) {
    obs::COORD_TRIPS_PHASE2.inc();
    let scalars = lane.scalars(lane.alpha, 0.0);
    let r2 = lane.slice.trip(program.phase(Phase::Phase2), scalars, exec);
    lane.rr = ret_scalar(&r2, ScalarRole::Rr);
    lane.rz_new = ret_scalar(&r2, ScalarRole::Rz);
}

/// A converged lane dispatches the exit trip (M3 alone) and frees its
/// slot; a live one runs Phase-3 with beta bound.
fn lane_phase3_or_exit<D: InstDispatch>(
    cfg: &CoordinatorConfig,
    program: &Program,
    lane: &mut LaneState,
    exec: &mut D,
) {
    if lane.rr <= cfg.tol {
        let scalars = lane.scalars(lane.alpha, 0.0);
        lane.slice.trip(&program.exit, scalars, exec);
        note_exit(lane);
        return;
    }
    let beta = lane.rz_new / lane.rz;
    let scalars = lane.scalars(lane.alpha, beta);
    lane.slice.trip(program.phase(Phase::Phase3), scalars, exec);
    note_phase3(cfg, lane);
}

/// The steady-state per-lane trip loop (phases 1–3 until every lane
/// retires), with the staged block-SpMV pass riding ahead of each SpMV
/// round while `block` holds.  Factored out of
/// [`Coordinator::solve_chunk`] so lanes the resident path gathers out
/// mid-solve resume on exactly the walk they would have run all along.
fn run_lane_loop<D: InstDispatch>(
    cfg: &CoordinatorConfig,
    program: &Program,
    lanes: &mut [LaneState],
    exec: &mut D,
    mut block: bool,
) {
    while lanes.iter().any(|l| l.live) {
        if block {
            block = block_spmv_pass(lanes, exec, false, true);
        }
        for lane in lanes.iter_mut().filter(|l| l.live) {
            lane_phase1(program, lane, exec);
        }
        for lane in lanes.iter_mut().filter(|l| l.live) {
            lane_phase2(program, lane, exec);
        }
        for lane in lanes.iter_mut().filter(|l| l.live) {
            lane_phase3_or_exit(cfg, program, lane, exec);
        }
    }
}

/// [`run_lane_loop`] with each trip fanned across the pool
/// ([`fan_trips`]) — the parallel chunk walk's steady-state loop.
#[allow(clippy::too_many_arguments)]
fn run_lane_loop_parallel<D: InstDispatch + Send>(
    pool: &WorkerPool,
    helpers: usize,
    cfg: &CoordinatorConfig,
    program: &Program,
    lanes: &mut [LaneState],
    execs: &mut [D],
    mut block: bool,
) {
    while lanes.iter().any(|l| l.live) {
        if block {
            block = block_spmv_pass(lanes, &mut execs[0], false, true);
        }
        fan_trips(pool, helpers, lanes, execs, true, |l, e| lane_phase1(program, l, e));
        fan_trips(pool, helpers, lanes, execs, true, |l, e| lane_phase2(program, l, e));
        fan_trips(pool, helpers, lanes, execs, true, |l, e| {
            lane_phase3_or_exit(cfg, program, l, e)
        });
    }
}

/// The per-lane starts of one chunk: the caller's x0 slices, or
/// `zeros` for every lane when none were given.  Shared by both batch
/// entry points so the chunking seam cannot drift between them.
fn x0_for_chunk<'x>(
    x0: Option<&[&'x [f64]]>,
    zeros: &'x [f64],
    lanes: std::ops::Range<usize>,
) -> Vec<&'x [f64]> {
    lanes.map(|k| x0.map_or(zeros, |xs| xs[k])).collect()
}

/// Shape checks shared by both batch entry points.
fn check_batch_shapes(rhs: &[&[f64]], x0: Option<&[&[f64]]>) {
    let n = rhs[0].len();
    for b in rhs {
        assert_eq!(b.len(), n, "every batch lane must share the vector length");
    }
    if let Some(x0s) = x0 {
        assert_eq!(x0s.len(), rhs.len(), "one x0 per right-hand side");
        for x in x0s {
            assert_eq!(x.len(), n, "x0 length must match the right-hand side");
        }
    }
}

/// One block-CG SpMV round: gather the selected lanes' SpMV inputs (x
/// on the merged-init round, p on the steady rounds) into an
/// interleaved lane-major block, stream the matrix **once** through
/// [`InstDispatch::batch_spmv`], and scatter the outputs into each
/// lane's staged ap with the [`VectorFile::block_ap_staged`] handshake
/// set — the lanes' M1 instructions then consume the staged stream.
/// Retired lanes are never gathered (`only_live`), so the inner loop's
/// work tracks the *live* lane count.  Returns whether block mode stays
/// on: `false` means the backend declined and the caller should fall
/// back to per-lane SpMV for the rest of the chunk (nothing was staged).
///
/// Gathering the inputs and scattering the outputs each move `n·L`
/// vector elements across the block boundary — `2·n·L` per pass on
/// [`crate::precision::stats::vector_element_moves`].  That is exactly
/// the traffic the resident arenas delete, so a single selected lane
/// (nothing to amortize the staging over) skips the pass and lets its
/// M1 stream the matrix per-lane: same nnz traffic, zero moves.
fn block_spmv_pass<D: InstDispatch>(
    lanes: &mut [LaneState],
    exec: &mut D,
    use_x: bool,
    only_live: bool,
) -> bool {
    let picked: Vec<usize> = lanes
        .iter()
        .enumerate()
        .filter(|(_, l)| !only_live || l.live)
        .map(|(k, _)| k)
        .collect();
    let Some(&first) = picked.first() else {
        return true; // nothing to stage; keep the mode on
    };
    if picked.len() == 1 {
        return true; // single lane: per-lane M1 is the cheaper dispatch
    }
    let n = lanes[first].slice.mem.x.len();
    // Lanes running different precision schemes cannot share one matrix
    // pass — the decode width differs — so the pass runs once per
    // *scheme group*, in [`Scheme::ALL`] order (deterministic grouping;
    // a static batch is always one group and takes exactly the
    // pre-adaptive single-pass path, no `bind_scheme` call).  A lone
    // lane in its group skips staging like a lone lane in the batch:
    // its per-lane M1 streams the same nnz bytes with zero moves (the
    // adaptive bind in [`lane_phase1`] has set its scheme).
    for scheme in Scheme::ALL {
        let group: Vec<usize> =
            picked.iter().copied().filter(|&k| lanes[k].ctrl.current() == scheme).collect();
        if group.len() < 2 {
            continue;
        }
        if lanes[group[0]].ctrl.is_adaptive() {
            exec.bind_scheme(scheme);
        }
        let l = group.len();
        let mut xs = vec![0.0; n * l];
        for (j, &k) in group.iter().enumerate() {
            let mem = &lanes[k].slice.mem;
            let src = if use_x { &mem.x } else { &mem.p };
            for (i, v) in src.iter().enumerate() {
                xs[i * l + j] = *v;
            }
        }
        let mut ys = vec![0.0; n * l];
        if !exec.batch_spmv(&xs, &mut ys, l) {
            // Lanes an earlier group staged still consume their staged
            // ap (it is exactly what their M1 would have computed); the
            // rest fall back to per-lane streaming with everyone else.
            obs::COORD_BLOCK_DEGRADE_PER_LANE.inc();
            return false;
        }
        for (j, &k) in group.iter().enumerate() {
            let mem = &mut lanes[k].slice.mem;
            for (i, dst) in mem.stage_ap.iter_mut().enumerate() {
                *dst = ys[i * l + j];
            }
            mem.block_ap_staged = true;
        }
        stats::add_vector_element_moves(2 * (n * l) as u64);
    }
    true
}

// --------------------------------------------------------------------
// Resident block state: the lane-major block is the *resident*
// representation for the whole batched solve.  x/p/r/ap (and the staged
// streams, z included) live in interleaved arenas from program issue to
// converged exit; the batch SpMV and the block vector ops read and
// write them in place, and a Type-III commit is a whole-arena swap.
// Steady-state iterations therefore move **zero** vector elements
// across the block boundary (counted on
// [`crate::precision::stats::vector_element_moves`]); elements move
// only at genuine boundaries — batch entry, lane retirement, and the
// gather-out fallback.
// --------------------------------------------------------------------

/// The resident value plane of one chunk: one interleaved lane-major
/// arena per vector, `slots[j]` naming the lane that owns column `j`.
/// Slots only ever hold live lanes — retirement extracts the lane's x
/// and compacts the survivors, so inner-loop work tracks the live
/// count exactly as per-lane dispatch's retired-lane skip does.
struct BlockArenas {
    /// Rows per lane.
    n: usize,
    /// Arena column -> index into the chunk's lane vec.
    slots: Vec<usize>,
    /// Committed (HBM) x.
    x: Vec<f64>,
    /// Committed r.
    r: Vec<f64>,
    /// Committed p.
    p: Vec<f64>,
    /// Committed ap.
    ap: Vec<f64>,
    /// Staged (on-chip stream) x.
    stage_x: Vec<f64>,
    /// Staged r.
    stage_r: Vec<f64>,
    /// Staged p.
    stage_p: Vec<f64>,
    /// Staged ap.
    stage_ap: Vec<f64>,
    /// z: on-chip only (§5.3), staged, never committed.
    stage_z: Vec<f64>,
}

impl BlockArenas {
    /// Interleave the chunk's starts into resident arenas — x0 columns
    /// into x, b columns into r (the same merged-init convention as
    /// [`VectorFile::new`]: init's M4 turns r into b - A·x0 in place).
    /// The one-time entry cost is `2·n·L` element moves; every other
    /// arena starts zeroed, which is initialization, not movement.
    fn gather_in(rhs: &[&[f64]], x0: &[&[f64]]) -> Self {
        let n = rhs[0].len();
        let l = rhs.len();
        let mut x = vec![0.0; n * l];
        let mut r = vec![0.0; n * l];
        for (j, (b, xs)) in rhs.iter().zip(x0).enumerate() {
            for i in 0..n {
                x[i * l + j] = xs[i];
                r[i * l + j] = b[i];
            }
        }
        stats::add_vector_element_moves(2 * (n * l) as u64);
        Self {
            n,
            slots: (0..l).collect(),
            x,
            r,
            p: vec![0.0; n * l],
            ap: vec![0.0; n * l],
            stage_x: vec![0.0; n * l],
            stage_r: vec![0.0; n * l],
            stage_p: vec![0.0; n * l],
            stage_ap: vec![0.0; n * l],
            stage_z: vec![0.0; n * l],
        }
    }

    /// Live lanes resident in the arenas.
    fn lanes(&self) -> usize {
        self.slots.len()
    }

    // A Type-III write-back on the resident plane: the staged arena
    // *becomes* the committed arena.  A swap, not a copy — zero element
    // moves, which is the whole point of residency.
    fn commit_x(&mut self) {
        std::mem::swap(&mut self.x, &mut self.stage_x);
    }
    fn commit_r(&mut self) {
        std::mem::swap(&mut self.r, &mut self.stage_r);
    }
    fn commit_p(&mut self) {
        std::mem::swap(&mut self.p, &mut self.stage_p);
    }
    fn commit_ap(&mut self) {
        std::mem::swap(&mut self.ap, &mut self.stage_ap);
    }

    /// Drop every column not in `keep` (ascending old-column indices),
    /// repacking the committed arenas in place — the forward walk's
    /// write index never passes its read index, so no scratch buffer.
    /// Costs `4·n·keep.len()` element moves; called only when a lane
    /// actually retired, so steady-state iterations never pay it.
    fn compact(&mut self, keep: &[usize]) {
        let old_l = self.lanes();
        let new_l = keep.len();
        if new_l == old_l {
            return;
        }
        let n = self.n;
        for arena in [&mut self.x, &mut self.r, &mut self.p, &mut self.ap] {
            for i in 0..n {
                for (j2, &j) in keep.iter().enumerate() {
                    arena[i * new_l + j2] = arena[i * old_l + j];
                }
            }
            arena.truncate(n * new_l);
        }
        for stage in [
            &mut self.stage_x,
            &mut self.stage_r,
            &mut self.stage_p,
            &mut self.stage_ap,
            &mut self.stage_z,
        ] {
            // Staged contents are dead across iteration boundaries;
            // only the capacity needs to match the surviving block.
            stage.truncate(n * new_l);
        }
        self.slots = keep.iter().map(|&j| self.slots[j]).collect();
        stats::add_vector_element_moves((4 * n * new_l) as u64);
    }
}

/// One lane's column of an interleaved lane-major arena, deinterleaved.
fn arena_col(arena: &[f64], n: usize, l: usize, j: usize) -> Vec<f64> {
    (0..n).map(|i| arena[i * l + j]).collect()
}

/// Extract every just-retired lane's solution out of the committed x
/// arena (`n` moves per retiring lane — its converged-exit boundary
/// cost) and compact the arenas down to the survivors.
fn retire_and_compact(ar: &mut BlockArenas, lanes: &mut [LaneState]) {
    let l = ar.lanes();
    let mut keep = Vec::with_capacity(l);
    let mut any_retired = false;
    for j in 0..l {
        let k = ar.slots[j];
        if lanes[k].live {
            keep.push(j);
        } else {
            any_retired = true;
            lanes[k].slice.mem.x = arena_col(&ar.x, ar.n, l, j);
            stats::add_vector_element_moves(ar.n as u64);
        }
    }
    if any_retired {
        ar.compact(&keep);
    }
}

/// Materialize every still-resident lane's per-lane [`VectorFile`] from
/// the committed arenas so the per-lane walk can finish the solve:
/// x/r/p/ap columns out (`4·n` moves per lane), b restored from the
/// caller's right-hand side, staging buffers sized (their contents are
/// dead between trips).  Called only at an iteration boundary, where
/// the committed plane plus each lane's scalar slots are exactly the
/// state the per-lane loop resumes from — so the continuation is
/// bitwise the walk that would have run all along.
fn gather_out(ar: &mut BlockArenas, lanes: &mut [LaneState], rhs: &[&[f64]]) {
    let l = ar.lanes();
    for j in 0..l {
        let k = ar.slots[j];
        let mem = &mut lanes[k].slice.mem;
        mem.x = arena_col(&ar.x, ar.n, l, j);
        mem.r = arena_col(&ar.r, ar.n, l, j);
        mem.p = arena_col(&ar.p, ar.n, l, j);
        mem.ap = arena_col(&ar.ap, ar.n, l, j);
        mem.b = rhs[k].to_vec();
        mem.stage_x = vec![0.0; ar.n];
        mem.stage_r = vec![0.0; ar.n];
        mem.stage_p = vec![0.0; ar.n];
        mem.stage_ap = vec![0.0; ar.n];
        mem.stage_z = vec![0.0; ar.n];
        stats::add_vector_element_moves(4 * ar.n as u64);
    }
    ar.slots.clear();
}

/// The steady-round batch SpMV on the resident arenas, precision-aware.
/// When every resident lane runs the same scheme — always true in
/// static mode, and the common case in adaptive mode — the matrix
/// streams straight from the p arena into the staged-ap arena in place,
/// exactly the pre-adaptive pass (zero moves; `bind_scheme` only when
/// adaptive).  A *mixed* round — some lanes escalated, others not —
/// cannot share a decode width, so each scheme group gathers its
/// columns into scratch, streams its pass, and scatters back: `2·n·g`
/// counted element moves per g-lane group, paid only on mixed rounds.
/// Returns `false` if the backend declined (the caller gathers out).
fn resident_batch_spmv<D: InstDispatch>(
    ar: &mut BlockArenas,
    lanes: &[LaneState],
    exec: &mut D,
) -> bool {
    let l = ar.lanes();
    let schemes: Vec<Scheme> = ar.slots.iter().map(|&k| lanes[k].ctrl.current()).collect();
    if schemes.iter().all(|&s| s == schemes[0]) {
        bind_lane_scheme(&lanes[ar.slots[0]], exec);
        return exec.batch_spmv(&ar.p, &mut ar.stage_ap, l);
    }
    let n = ar.n;
    for scheme in Scheme::ALL {
        let cols: Vec<usize> = (0..l).filter(|&j| schemes[j] == scheme).collect();
        if cols.is_empty() {
            continue;
        }
        // Mixed rounds only arise in adaptive mode: bind unconditionally.
        exec.bind_scheme(scheme);
        let g = cols.len();
        let mut xs = vec![0.0; n * g];
        for (j2, &j) in cols.iter().enumerate() {
            for i in 0..n {
                xs[i * g + j2] = ar.p[i * l + j];
            }
        }
        let mut ys = vec![0.0; n * g];
        if !exec.batch_spmv(&xs, &mut ys, g) {
            return false;
        }
        for (j2, &j) in cols.iter().enumerate() {
            for i in 0..n {
                ar.stage_ap[i * l + j] = ys[i * g + j2];
            }
        }
        stats::add_vector_element_moves(2 * (n * g) as u64);
    }
    true
}

/// One chunk on the resident block plane.  Every round runs its
/// arithmetic batch-wide over the arenas (the batch SpMV plus the
/// [`InstDispatch`] block vector ops, each bitwise the per-lane module
/// per lane), then issues the per-lane trips through
/// [`LaneSlice::issue`] — identical instruction streams, traces, and
/// acks, with arena swaps playing the commit role.  Scalar bookkeeping
/// goes through the same `note_*` helpers as the per-lane walk, so
/// liveness, traces, and iteration counts cannot drift.
///
/// Returns `None` if the backend's batch SpMV declined before anything
/// was issued (the caller restarts the chunk per-lane from scratch);
/// `Some(lanes)` otherwise, where any lane still live gathered out into
/// its per-lane [`VectorFile`] (mid-solve decline, or a lone survivor
/// not worth batching) and finishes on the caller's per-lane loop.
fn solve_chunk_resident<D: InstDispatch>(
    cfg: &CoordinatorConfig,
    program: &Program,
    exec: &mut D,
    rhs: &[&[f64]],
    x0: &[&[f64]],
) -> Option<Vec<LaneState>> {
    let fallback = exec.active_scheme();
    let mut lanes: Vec<LaneState> = (0..rhs.len())
        .map(|k| {
            let ctrl = PrecisionController::for_mode(cfg.precision, fallback, cfg.tol);
            LaneState::new_resident(program.lane_offset_beats(k as u32), cfg, ctrl)
        })
        .collect();
    let mut ar = BlockArenas::gather_in(rhs, x0);
    let l = ar.lanes();

    // ---- merged init round: M1 M4 M8 M5 M6 M7, commits r and p ----
    // M1 streams the matrix once for the whole batch, straight from the
    // x arena into the staged-ap arena — in place, nothing gathered or
    // scattered.  This is also the batch kernel's one chance to decline
    // cleanly: nothing has been issued yet.  Every lane enters at the
    // controller's start scheme, so the init pass is always uniform.
    bind_lane_scheme(&lanes[0], exec);
    if !exec.batch_spmv(&ar.x, &mut ar.stage_ap, l) {
        obs::COORD_BLOCK_DEGRADE_PER_LANE.inc();
        return None;
    }
    // M4 with init's pre-bound alpha = 1: r = r - ap, ap on-chip.
    ar.stage_r.copy_from_slice(&ar.r);
    exec.block_axpy(&vec![-1.0; l], &ar.stage_ap, &mut ar.stage_r);
    // M8 (hoisted): rr per lane.
    let mut rr = vec![0.0; l];
    exec.block_dots(&ar.stage_r, &ar.stage_r, &mut rr);
    // M5: z = r / diag.
    exec.block_left_divide(&ar.stage_r, &mut ar.stage_z, l);
    // M6: rz per lane.
    let mut rz = vec![0.0; l];
    exec.block_dots(&ar.stage_r, &ar.stage_z, &mut rz);
    // M7 on the merged init (no p yet): the beta = 0 update degenerates
    // to the stream-through copy p = z.
    ar.stage_p.copy_from_slice(&ar.stage_z);
    for (j, lane) in lanes.iter_mut().enumerate() {
        let scalars = lane.scalars(1.0, 0.0);
        lane.slice.issue(&program.init, scalars);
        note_init(cfg, lane, rz[j], rr[j]);
    }
    ar.commit_r();
    ar.commit_p();
    retire_and_compact(&mut ar, &mut lanes);

    // ---- steady-state rounds ----
    loop {
        let l = ar.lanes();
        if l == 0 {
            return Some(lanes); // every lane retired in residence
        }
        if l == 1 {
            // A lone survivor has nothing left to batch over: gather it
            // out and let the per-lane walk finish — the same
            // single-lane short-circuit the staged pass takes.
            obs::COORD_BLOCK_GATHER_OUT_LANES.inc();
            gather_out(&mut ar, &mut lanes, rhs);
            return Some(lanes);
        }
        // ---- phase 1: M1, M2; commits ap ----
        if !resident_batch_spmv(&mut ar, &lanes, exec) {
            // Mid-solve decline: we are at an iteration boundary, so
            // the committed plane gathers out cleanly.
            obs::COORD_BLOCK_DEGRADE_PER_LANE.inc();
            obs::COORD_BLOCK_GATHER_OUT_LANES.add(l as u64);
            gather_out(&mut ar, &mut lanes, rhs);
            return Some(lanes);
        }
        let mut pap = vec![0.0; l];
        exec.block_dots(&ar.p, &ar.stage_ap, &mut pap);
        for (j, &k) in ar.slots.iter().enumerate() {
            let lane = &mut lanes[k];
            let scalars = lane.scalars(0.0, 0.0);
            obs::COORD_TRIPS_PHASE1.inc();
            lane.slice.issue(program.phase(Phase::Phase1), scalars);
            lane.alpha = lane.rz / pap[j];
        }
        ar.commit_ap();

        // ---- phase 2: M4 M8 M5 M6; no commits ----
        ar.stage_r.copy_from_slice(&ar.r);
        let neg_alphas: Vec<f64> = ar.slots.iter().map(|&k| -lanes[k].alpha).collect();
        exec.block_axpy(&neg_alphas, &ar.ap, &mut ar.stage_r);
        let mut rr = vec![0.0; l];
        exec.block_dots(&ar.stage_r, &ar.stage_r, &mut rr);
        exec.block_left_divide(&ar.stage_r, &mut ar.stage_z, l);
        let mut rz_new = vec![0.0; l];
        exec.block_dots(&ar.stage_r, &ar.stage_z, &mut rz_new);
        for (j, &k) in ar.slots.iter().enumerate() {
            let lane = &mut lanes[k];
            let scalars = lane.scalars(lane.alpha, 0.0);
            obs::COORD_TRIPS_PHASE2.inc();
            lane.slice.issue(program.phase(Phase::Phase2), scalars);
            lane.rr = rr[j];
            lane.rz_new = rz_new[j];
        }

        // ---- phase 3 / converged exit; commits x, plus p and r when
        // any lane runs phase 3 ----
        // Phase 3's M4/M5 recompute phase 2's stage_r / stage_z
        // bit-identically from the same committed inputs, and the M5
        // write-back commits the recomputed stream (§5.3).  The arenas
        // still hold exactly those bits, so the recompute is a no-op
        // here — commit what is already staged.
        let any_steady = ar.slots.iter().any(|&k| lanes[k].rr > cfg.tol);
        if any_steady {
            // M7: p' = z + beta·p, the old p staying committed for M3.
            // A converged lane's column rides along with beta = 0; its
            // committed p is dead after this round (only x leaves the
            // arenas at retirement), so the ride-along is unobservable.
            ar.stage_p.copy_from_slice(&ar.p);
            let betas: Vec<f64> = ar
                .slots
                .iter()
                .map(|&k| {
                    let lane = &lanes[k];
                    if lane.rr <= cfg.tol {
                        0.0
                    } else {
                        lane.rz_new / lane.rz
                    }
                })
                .collect();
            exec.block_update_p(&betas, &ar.stage_z, &mut ar.stage_p);
        }
        // M3: x' = x + alpha·p_old.  The phase-3 and converged-exit
        // trips bind the same alpha, so one batch-wide axpy serves both.
        ar.stage_x.copy_from_slice(&ar.x);
        let alphas: Vec<f64> = ar.slots.iter().map(|&k| lanes[k].alpha).collect();
        exec.block_axpy(&alphas, &ar.p, &mut ar.stage_x);
        for &k in &ar.slots {
            let lane = &mut lanes[k];
            if lane.rr <= cfg.tol {
                let scalars = lane.scalars(lane.alpha, 0.0);
                lane.slice.issue(&program.exit, scalars);
                note_exit(lane);
            } else {
                let scalars = lane.scalars(lane.alpha, lane.rz_new / lane.rz);
                lane.slice.issue(program.phase(Phase::Phase3), scalars);
                note_phase3(cfg, lane);
            }
        }
        ar.commit_x();
        if any_steady {
            ar.commit_p();
            ar.commit_r();
        }
        retire_and_compact(&mut ar, &mut lanes);
    }
}

/// Fan one trip across the (live) lanes through the pool's indexed
/// arena ([`WorkerPool::run_scoped_indexed`]): lanes are claimed off a
/// shared atomic cursor, so a trip boxes one drain loop per
/// participating worker instead of one job per lane (PERF §11), with an
/// implicit barrier when the scope drains.  `helpers == 0` (or a
/// single live lane) degenerates to the sequential lane-minor walk on
/// the calling thread (same issue order as
/// [`Coordinator::solve_batch`]) — without boxing any jobs.
fn fan_trips<D, F>(
    pool: &WorkerPool,
    helpers: usize,
    lanes: &mut [LaneState],
    execs: &mut [D],
    only_live: bool,
    step: F,
) where
    D: InstDispatch + Send,
    F: Fn(&mut LaneState, &mut D) + Sync,
{
    let mut pairs: Vec<(*mut LaneState, *mut D)> = lanes
        .iter_mut()
        .zip(execs.iter_mut())
        .filter(|(l, _)| !only_live || l.live)
        .map(|(lane, exec)| (lane as *mut LaneState, exec as *mut D))
        .collect();
    if helpers == 0 || pairs.len() <= 1 {
        for &(lane, exec) in &pairs {
            // SAFETY: the pointers came from disjoint `&mut` borrows
            // that outlive this loop.
            unsafe { step(&mut *lane, &mut *exec) };
        }
        return;
    }
    let base = SyncPtr(pairs.as_mut_ptr());
    pool.run_scoped_indexed(pairs.len(), helpers, &|i| {
        // SAFETY: run_scoped_indexed's atomic cursor hands each index
        // to exactly one worker, and each slot holds pointers derived
        // from disjoint `&mut` borrows that outlive the call, so this
        // is the only live reference to lane/executor `i`.
        let (lane, exec) = unsafe { *base.0.add(i) };
        unsafe { step(&mut *lane, &mut *exec) };
    });
}

/// A raw pointer the trip fan-out can share across workers.  Safety is
/// argued at each use site: every slot behind the pointer is
/// dereferenced by exactly one worker.
struct SyncPtr<T>(*mut T);
unsafe impl<T> Send for SyncPtr<T> {}
unsafe impl<T> Sync for SyncPtr<T> {}

// --------------------------------------------------------------------
// Native executor: an instruction interpreter over the module
// implementations of modules::compute.
// --------------------------------------------------------------------

use crate::engine::PreparedMatrix;
use crate::isa::InstCmp;
use crate::modules::compute::{AxpyModule, LeftDivideModule, UpdatePModule};
use crate::modules::fsm::Endpoint;
use crate::program::{CompStep, PhaseProgram};
use crate::sparse::{pack_nnz_streams, NnzStream, DEP_DIST_SERPENS};
use crate::vsr::{Module, Vector};

/// Interprets compiled Type-II instructions with the native module
/// implementations.  The SpMV runs on the prepared-matrix plan
/// (nnz-balanced engine kernels — **bitwise identical** to the serial
/// gather at any thread count, so the whole instruction-driven solve is
/// bit-for-bit [`crate::solver::jpcg_solve`]); an opt-in Serpens-stream
/// path replays the scheduled nnz streams instead (stream-order
/// accumulation — time-plane-faithful, not bitwise-oracle-exact).
pub struct NativeExecutor<'a> {
    /// The system matrix.
    pub a: &'a CsrMatrix,
    /// SpMV precision scheme (Table 1).
    pub scheme: Scheme,
    stream: Option<NnzStream>,
    /// Owned when the executor derived its own plan, borrowed when a
    /// caller's prepared matrix is being served ([`Self::with_plan`]).
    prep: std::borrow::Cow<'a, PreparedMatrix<'a>>,
}

impl<'a> NativeExecutor<'a> {
    /// An executor over a fresh solve plan sized to the machine's
    /// available parallelism.
    pub fn new(a: &'a CsrMatrix, scheme: Scheme) -> Self {
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(a, scheme, threads)
    }

    /// Explicit thread budget for the engine SpMV (1 = serial).
    pub fn with_threads(a: &'a CsrMatrix, scheme: Scheme, threads: usize) -> Self {
        Self {
            a,
            scheme,
            stream: None,
            prep: std::borrow::Cow::Owned(PreparedMatrix::new(a, threads)),
        }
    }

    /// Serve an already-prepared solve plan (cached f32 view, diagonal,
    /// partition) by reference instead of deriving or copying one —
    /// what
    /// [`PreparedMatrix::solve_batch`](crate::engine::PreparedMatrix::solve_batch)
    /// uses so serving a batch never re-derives (or clones) the matrix
    /// caches.
    pub fn with_plan(prep: &'a PreparedMatrix<'a>, scheme: Scheme) -> Self {
        Self { a: prep.matrix(), scheme, stream: None, prep: std::borrow::Cow::Borrowed(prep) }
    }

    /// Mix-V3 over the scheduled Serpens nnz streams (§6 stream value
    /// plane).  Accumulation follows the out-of-order stream schedule,
    /// so this path trades the bitwise solver oracle for stream
    /// fidelity.
    pub fn with_serpens_stream(a: &'a CsrMatrix) -> Self {
        Self {
            a,
            scheme: Scheme::MixV3,
            stream: Some(pack_nnz_streams(a, DEP_DIST_SERPENS)),
            prep: std::borrow::Cow::Owned(PreparedMatrix::new(a, 1)),
        }
    }

    /// The underlying solve plan (partition, cached diagonal/values).
    pub fn plan(&self) -> &PreparedMatrix<'a> {
        &self.prep
    }

    fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        match &self.stream {
            Some(s) => s.replay_mixv3(x, y),
            None => self.prep.spmv(self.scheme, x, y),
        }
    }

    /// The delay-buffer dot, lane-grouped across the plan's thread
    /// budget — bitwise the serial
    /// [`DotModule`](crate::modules::compute::DotModule) kernel
    /// ([`crate::engine::dot_delay_parallel`]'s fixed-partition
    /// contract), so M2/M6/M8 speed up without touching any oracle.
    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        crate::engine::dot_delay_parallel(a, b, self.prep.threads())
    }

    /// Execute one Type-II instruction.  Input *sources* follow the
    /// compiled endpoints: a `Memory` endpoint reads the committed
    /// (HBM) vector, a `Module` endpoint reads the staged on-chip
    /// stream — the reuse edges validated at compile time.
    fn exec_cmp(&self, step: &CompStep, inst: &InstCmp, mem: &mut VectorFile) -> Option<f64> {
        match step.module {
            Module::M1 => {
                // SpMV input per the Type-I routing: x0 on the merged
                // init trip, p on the steady trips.  Under block-CG
                // dispatch a batch-wide pass already streamed the
                // matrix and staged this lane's ap — M1 consumes the
                // staged stream instead of re-streaming (the Type-II
                // issue, dirty bit, and write-back are unchanged).
                if mem.block_ap_staged {
                    mem.block_ap_staged = false;
                } else if step.inputs.iter().any(|(v, _)| *v == Vector::X) {
                    self.spmv_into(&mem.x, &mut mem.stage_ap);
                } else {
                    self.spmv_into(&mem.p, &mut mem.stage_ap);
                }
                mem.mark_dirty(Vector::Ap);
                None
            }
            Module::M2 => {
                // pap: p from memory, ap streamed on-chip from M1.
                Some(self.dot(&mem.p, &mem.stage_ap))
            }
            Module::M4 => {
                // r' = r - alpha·ap into the staging stream.  Phase-2
                // keeps it on-chip; Phase-3 recomputes the identical
                // bits and the M5 write-back commits them (§5.3).
                mem.stage_r.copy_from_slice(&mem.r);
                let ap_onchip = step
                    .inputs
                    .iter()
                    .any(|(v, e)| *v == Vector::Ap && matches!(e, Endpoint::Module(_)));
                if ap_onchip {
                    // Merged init: ap arrives straight from M1.
                    let (stage_ap, stage_r) = (&mem.stage_ap, &mut mem.stage_r);
                    AxpyModule.run(-inst.alpha, stage_ap, stage_r);
                } else {
                    AxpyModule.run(-inst.alpha, &mem.ap, &mut mem.stage_r);
                }
                mem.mark_dirty(Vector::R);
                None
            }
            Module::M5 => {
                LeftDivideModule.run(&mem.stage_r, self.prep.diag(), &mut mem.stage_z);
                None
            }
            Module::M6 => Some(self.dot(&mem.stage_r, &mem.stage_z)),
            Module::M8 => Some(self.dot(&mem.stage_r, &mem.stage_r)),
            Module::M7 => {
                if step.inputs.iter().any(|(v, _)| *v == Vector::P) {
                    mem.stage_p.copy_from_slice(&mem.p);
                    UpdatePModule.run(inst.alpha, &mem.stage_z, &mut mem.stage_p);
                } else {
                    // Merged init: no p yet — the beta = 0 update
                    // degenerates to the stream-through copy p = z.
                    mem.stage_p.copy_from_slice(&mem.stage_z);
                }
                mem.mark_dirty(Vector::P);
                None
            }
            Module::M3 => {
                // x' = x + alpha·p_old: the M7-forwarded stream carries
                // the old-p lane (Fig. 5), i.e. the still-committed p.
                mem.stage_x.copy_from_slice(&mem.x);
                AxpyModule.run(inst.alpha, &mem.p, &mut mem.stage_x);
                mem.mark_dirty(Vector::X);
                None
            }
        }
    }
}

impl InstDispatch for NativeExecutor<'_> {
    fn dispatch(
        &mut self,
        prog: &PhaseProgram,
        cmds: &[InstCmp],
        mem: &mut VectorFile,
    ) -> DispatchReturn {
        debug_assert_eq!(prog.comp_steps.len(), cmds.len());
        let mut ret = DispatchReturn::default();
        for (step, inst) in prog.comp_steps.iter().zip(cmds) {
            let scalar = self.exec_cmp(step, inst, mem);
            match step.scalar {
                Some(ScalarRole::Pap) => ret.pap = scalar,
                Some(ScalarRole::Rz) => ret.rz = scalar,
                Some(ScalarRole::Rr) => ret.rr = scalar,
                None => {}
            }
        }
        ret
    }

    /// One nnz pass feeds every lane
    /// ([`crate::engine::spmv_block_parallel`] on the plan's partition),
    /// bitwise the per-lane [`PreparedMatrix::spmv`] per lane.  The
    /// Serpens stream replay declines: its accumulation follows the
    /// scheduled stream order, which has no batch kernel.
    fn batch_spmv(&mut self, xs: &[f64], ys: &mut [f64], lanes: usize) -> bool {
        if self.stream.is_some() {
            return false;
        }
        self.prep.spmv_block(self.scheme, xs, ys, lanes);
        true
    }

    /// Adaptive re-bind (PR 8): a decode-width change, not a data move —
    /// the prepared plan caches the f64 values and the f32 view side by
    /// side, so switching schemes is a field write and the next SpMV
    /// simply reads the other stream.  The Serpens replay path accepts
    /// the bind but keeps streaming Mix-V3: its accumulation schedule
    /// is baked at pack time (and its declining [`Self::batch_spmv`]
    /// already keeps it off the block paths).
    fn bind_scheme(&mut self, scheme: Scheme) {
        self.scheme = scheme;
    }

    fn active_scheme(&self) -> Scheme {
        self.scheme
    }

    /// The native backend serves the whole resident block family: its
    /// vector ops run on the engine's row-range-parallel block kernels
    /// (lane-axis-parallel for the dots), each bitwise the per-lane
    /// module kernel per lane.  Advertised even on the Serpens stream
    /// path — the vector plane is stream-independent — where the
    /// declining [`NativeExecutor::batch_spmv`] above still routes the
    /// resident request back to per-lane dispatch before any op runs.
    fn block_vector_ops(&self) -> bool {
        true
    }

    fn block_axpy(&mut self, alphas: &[f64], xs: &[f64], ys: &mut [f64]) {
        crate::engine::axpy_block_parallel(alphas, xs, ys, self.prep.partition());
    }

    fn block_left_divide(&mut self, rs: &[f64], zs: &mut [f64], lanes: usize) {
        crate::engine::left_divide_block_parallel(
            rs,
            self.prep.diag(),
            zs,
            lanes,
            self.prep.partition(),
        );
    }

    fn block_update_p(&mut self, betas: &[f64], zs: &[f64], ps: &mut [f64]) {
        crate::engine::update_p_block_parallel(betas, zs, ps, self.prep.partition());
    }

    fn block_dots(&mut self, a: &[f64], b: &[f64], out: &mut [f64]) {
        crate::engine::dot_block_parallel(a, b, out, self.prep.threads());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::{jpcg_solve, SolveOptions};
    use crate::sparse::synth;

    fn solve_native(a: &CsrMatrix, scheme: Scheme) -> CoordResult {
        let cfg = CoordinatorConfig { record_instructions: true, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(a, scheme);
        let b = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        coord.solve(&mut exec, &b, &x0)
    }

    #[test]
    fn coordinator_converges_and_solves() {
        let a = synth::laplace2d_shifted(900, 0.05);
        let res = solve_native(&a, Scheme::MixV3);
        assert!(res.converged, "rr={}", res.final_rr);
        let mut ax = vec![0.0; a.n];
        a.spmv_f64(&res.x, &mut ax);
        let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn coordinator_matches_reference_solver_iterations() {
        // The instruction-driven path runs the same arithmetic as the
        // monolithic reference solver — iteration counts are identical
        // (the bitwise oracle lives in tests/program_oracle.rs).
        let a = synth::banded_spd(1500, 12_000, 1e-4, 21);
        let coord = solve_native(&a, Scheme::MixV3);
        let refres = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        assert_eq!(coord.iters, refres.iters, "coord={} ref={}", coord.iters, refres.iters);
    }

    #[test]
    fn serpens_stream_path_still_converges() {
        // Same matrix the pre-refactor coordinator (which always ran the
        // stream replay for Mix-V3) was validated on with this margin.
        let a = synth::banded_spd(1500, 12_000, 1e-4, 21);
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut exec = NativeExecutor::with_serpens_stream(&a);
        let b = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        let res = coord.solve(&mut exec, &b, &x0);
        assert!(res.converged, "rr={}", res.final_rr);
        // Stream-order accumulation may move a few iterations relative
        // to the serial-gather oracle, but not many.
        let refres = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
        let diff = (res.iters as i64 - refres.iters as i64).abs();
        assert!(diff <= 5, "stream={} ref={}", res.iters, refres.iters);
    }

    #[test]
    fn fp64_scheme_uses_csr_path() {
        let a = synth::laplace2d_shifted(400, 0.1);
        let res = solve_native(&a, Scheme::Fp64);
        assert!(res.converged);
    }

    #[test]
    fn fp64_path_thread_count_is_bitwise_invisible() {
        // The engine-backed SpMV must not move a single iteration.
        let a = synth::banded_spd(1_000, 8_000, 1e-4, 57);
        let cfg = CoordinatorConfig::default();
        let solve_t = |threads: usize| {
            let mut coord = Coordinator::new(cfg);
            let mut exec = NativeExecutor::with_threads(&a, Scheme::Fp64, threads);
            let b = vec![1.0; a.n];
            let x0 = vec![0.0; a.n];
            coord.solve(&mut exec, &b, &x0)
        };
        let serial = solve_t(1);
        let parallel = solve_t(8);
        assert_eq!(serial.iters, parallel.iters);
        assert!(serial
            .x
            .iter()
            .zip(&parallel.x)
            .all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn instruction_trace_counts_scale_with_iterations() {
        let a = synth::laplace2d_shifted(400, 0.1);
        let res = solve_native(&a, Scheme::MixV3);
        // One M1 Type-II per iteration (phase 1) plus one on the merged
        // init trip.
        let m1 = res.instructions.count_for("M1");
        assert_eq!(m1 as u32, res.iters + 1, "m1={m1} iters={}", res.iters);
        // VecCtrl-p issues Type-I instructions in phase 1 (twice), on
        // the init trip, and in phase 3 / the exit trip.
        assert!(res.instructions.count_for("VecCtrl-p") >= m1);
    }

    #[test]
    fn early_exit_skips_phase3_modules() {
        let a = synth::laplace2d_shifted(400, 0.3); // converges quickly
        let res = solve_native(&a, Scheme::Fp64);
        assert!(res.converged);
        // M7 runs once on the merged init (p = z copy) and once per
        // phase-3 trip; the converged iteration dispatched the exit
        // trip instead, so: init + (iters - 1) = iters.
        let m7 = res.instructions.count_for("M7");
        assert_eq!(m7 as u32, res.iters, "M7 skipped on the final trip");
        // The exit trip ran M3 without M7.
        let m3 = res.instructions.count_for("M3");
        assert_eq!(m3 as u32, res.iters, "one M3 per phase-3/exit trip");
    }

    #[test]
    fn memory_acks_match_the_compiled_write_schedule() {
        // init writes r, p (2); each full iteration writes ap, r, p, x
        // (4); the converged iteration writes ap + x (2): 4·iters total.
        let a = synth::laplace2d_shifted(400, 0.1);
        let res = solve_native(&a, Scheme::MixV3);
        assert!(res.converged);
        assert_eq!(res.mem_acks as u32, 4 * res.iters);
    }

    #[test]
    fn static_solves_pin_the_backend_scheme_in_the_trace() {
        // Static mode never re-binds: the trace is the single pinned
        // scheme the executor was built with, covering every pass.
        let a = synth::laplace2d_shifted(400, 0.1);
        let res = solve_native(&a, Scheme::MixV3);
        assert_eq!(res.precision.events().len(), 1);
        assert_eq!(res.precision.events()[0].scheme, Scheme::MixV3);
        assert_eq!(res.precision.scheme_at(res.iters), Scheme::MixV3);
    }

    #[test]
    fn adaptive_mode_records_a_trace_and_still_converges() {
        use crate::precision::adaptive::AdaptivePolicy;
        let a = synth::banded_spd(1500, 12_000, 1e-4, 21);
        let cfg = CoordinatorConfig {
            precision: PrecisionMode::Adaptive(AdaptivePolicy::default()),
            ..Default::default()
        };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
        let b = vec![1.0; a.n];
        let x0 = vec![0.0; a.n];
        let res = coord.solve(&mut exec, &b, &x0);
        assert!(res.converged, "rr={}", res.final_rr);
        let events = res.precision.events();
        assert_eq!(events[0].pass, 0);
        assert_eq!(events[0].scheme, Scheme::MixV3, "lanes start on the policy's start scheme");
    }

    #[test]
    fn zero_b_converges_on_the_init_trip_alone() {
        let a = synth::laplace2d_shifted(100, 0.1);
        let cfg = CoordinatorConfig { record_instructions: true, ..Default::default() };
        let mut coord = Coordinator::new(cfg);
        let mut exec = NativeExecutor::new(&a, Scheme::MixV3);
        let res = coord.solve(&mut exec, &vec![0.0; a.n], &vec![0.0; a.n]);
        assert!(res.converged);
        assert_eq!(res.iters, 0);
        // The merged init ran (one M1), but no iteration trips did.
        assert_eq!(res.instructions.count_for("M1"), 1);
        assert_eq!(res.instructions.count_for("M2"), 0);
    }
}
