//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! A 2-D Poisson problem (N = 10 000) is solved twice:
//!
//! 1. **PJRT path** — the Rust global controller (L3) drives the JPCG
//!    phases by executing AOT-compiled JAX/Pallas HLO artifacts (L2/L1)
//!    on the CPU PJRT client. Python is NOT involved at runtime.
//! 2. **Native path** — the same controller drives the native module
//!    implementations.
//!
//! The two must agree on the solution and (almost exactly) on iteration
//! count; the run also reports the cycle model's solver-time estimate
//! for the simulated U280 build.  Results recorded in EXPERIMENTS.md
//! §E-E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_poisson
//! ```

use callipepla::coordinator::{Coordinator, CoordinatorConfig, NativeExecutor};
use callipepla::precision::Scheme;
use callipepla::runtime::{default_artifact_dir, PjrtExecutor, PjrtRuntime};
use callipepla::sim::{self, AccelSimConfig};
use callipepla::sparse::synth;

fn main() -> anyhow::Result<()> {
    let a = synth::laplace2d_shifted(10_000, 0.02);
    let b = vec![1.0; a.n];
    let x0 = vec![0.0; a.n];
    println!("e2e Poisson: n={} nnz={}", a.n, a.nnz());

    // ---- Path 1: coordinator -> PJRT artifacts (the 3-layer stack) ----
    let t0 = std::time::Instant::now();
    let mut rt = PjrtRuntime::new(default_artifact_dir())?;
    let mut exec = PjrtExecutor::new(&mut rt, &a, Scheme::MixV3)?;
    let cfg = CoordinatorConfig { record_trace: true, ..Default::default() };
    let mut coord = Coordinator::new(cfg);
    let pjrt = coord.solve(&mut exec, &b, &x0);
    let pjrt_calls = exec.calls;
    let pjrt_wall = t0.elapsed();
    println!(
        "PJRT  path: converged={} iters={} |r|^2={:.3e} executable_calls={} wall={pjrt_wall:?}",
        pjrt.converged, pjrt.iters, pjrt.final_rr, pjrt_calls
    );
    assert!(pjrt.converged, "PJRT path must converge");

    // Loss-curve analogue: residual trace (log it sparsely).
    let tr = pjrt.trace.values();
    println!("residual curve (iter, |r|^2):");
    let stride = (tr.len() / 10).max(1);
    for (i, rr) in tr.iter().enumerate() {
        if i % stride == 0 || i + 1 == tr.len() {
            println!("  {i:>6}  {rr:.6e}");
        }
    }

    // ---- Path 2: coordinator -> native modules ------------------------
    let t1 = std::time::Instant::now();
    let mut coord2 = Coordinator::new(CoordinatorConfig::default());
    let mut native_exec = NativeExecutor::new(&a, Scheme::MixV3);
    let native = coord2.solve(&mut native_exec, &b, &x0);
    let native_wall = t1.elapsed();
    println!(
        "native path: converged={} iters={} |r|^2={:.3e} wall={native_wall:?}",
        native.converged, native.iters, native.final_rr
    );

    // ---- Cross-check the two value planes -----------------------------
    let iter_gap = (pjrt.iters as i64 - native.iters as i64).abs();
    assert!(iter_gap <= 2, "PJRT vs native iteration gap {iter_gap}");
    let max_dx = pjrt
        .x
        .iter()
        .zip(&native.x)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("solution agreement: max |x_pjrt - x_native| = {max_dx:.3e}");
    assert!(max_dx < 1e-6, "planes diverged: {max_dx}");

    // And against the ground truth A x = b.
    let mut ax = vec![0.0; a.n];
    a.spmv_f64(&pjrt.x, &mut ax);
    let res_err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
    println!("ground truth: ||Ax - b||_inf = {res_err:.3e}");
    assert!(res_err < 1e-4);

    // ---- Time plane: what would this cost on the U280? ----------------
    let cal = AccelSimConfig::callipepla();
    let est = sim::solver_seconds(&cal, a.n, a.nnz(), pjrt.iters);
    let brk = sim::iteration_cycles(&cal, a.n, a.nnz());
    println!(
        "U280 estimate: {:.3} ms total ({} iters x {} cycles @ {:.0} MHz)",
        est * 1e3,
        pjrt.iters,
        brk.total,
        cal.hbm.freq_hz / 1e6
    );
    println!("e2e OK");
    Ok(())
}
