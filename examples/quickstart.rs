//! Quickstart: solve a small SPD system with the Callipepla JPCG solver
//! and compare the four precision schemes of Table 1.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use callipepla::precision::Scheme;
use callipepla::solver::{jpcg_solve, SolveOptions};
use callipepla::sparse::synth;

fn main() {
    // A 2-D Poisson problem (the "thermal" class of Table 3), ~10K dofs.
    let a = synth::laplace2d_shifted(10_000, 0.02);
    println!("matrix: n={} nnz={}", a.n, a.nnz());

    // 1. The shipping Callipepla configuration: Mix-V3 + delay-buffer
    //    dot products + out-of-order Serpens SpMV scheduling.
    let res = jpcg_solve(&a, None, None, &SolveOptions::callipepla());
    println!(
        "callipepla (Mix-V3): converged={} iters={} |r|^2={:.3e}",
        res.converged, res.iters, res.final_rr
    );
    assert!(res.converged, "quickstart must converge");

    // 2. Verify the solution actually solves A x = b.
    let mut ax = vec![0.0; a.n];
    a.spmv_f64(&res.x, &mut ax);
    let err = ax.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
    println!("solution check: ||Ax - b||_inf = {err:.3e}");

    // 3. Table-1 scheme comparison: same matrix, all four precisions.
    println!("\nscheme   converged iters   (Table 1 / Fig. 9: V3 ~ FP64, V1 worst)");
    for scheme in Scheme::ALL {
        let opts = SolveOptions { scheme, ..SolveOptions::default() };
        let r = jpcg_solve(&a, None, None, &opts);
        println!("{:<8} {:<9} {:<7}", scheme.name(), r.converged, r.iters);
    }
}
