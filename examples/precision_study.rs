//! Precision study: regenerates the Fig. 9 residual traces for the
//! three paper matrices (nasa2910, gyro_k, msc10848) under the five
//! settings — default FP64, Mix-V1/V2/V3, and the Callipepla on-board
//! configuration (Mix-V3 + delay-buffer dots + out-of-order SpMV).
//!
//! CSV traces land in `traces/`; the console prints the iteration at
//! which each setting first crosses 1e-12 (or "never").
//!
//! ```bash
//! cargo run --release --example precision_study [scale]
//! ```

use callipepla::bench_harness::tables::fig9_traces;
use callipepla::sparse::synth;

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05);
    std::fs::create_dir_all("traces").expect("mkdir traces");

    for id in ["M7", "M13", "M15"] {
        let spec = synth::find_spec(id).unwrap();
        let a = spec.generate(scale);
        println!(
            "\n{} ({}): n={} nnz={} [paper CPU iters: {}]",
            spec.id, spec.paper_name, a.n, a.nnz(), spec.cpu_iters
        );
        println!("{:<22} {:>12} {:>14}", "setting", "iters<=1e-12", "final |r|^2");
        for (label, csv) in fig9_traces(&a, 20_000) {
            // Parse our own CSV tail for the summary line.
            let last = csv.lines().last().unwrap_or("0,0");
            let mut it = last.split(',');
            let final_iter: usize = it.next().unwrap().parse().unwrap_or(0);
            let final_rr: f64 = it.next().unwrap().parse().unwrap_or(f64::NAN);
            let crossed = if final_rr < 1e-12 {
                format!("{final_iter}")
            } else {
                "never".to_string()
            };
            println!("{label:<22} {crossed:>12} {final_rr:>14.3e}");
            let path = format!("traces/fig9_{}_{label}.csv", spec.paper_name);
            std::fs::write(&path, &csv).expect("write trace");
        }
        println!("traces written to traces/fig9_{}_*.csv", spec.paper_name);
    }
    println!("\nExpected shape (paper Fig. 9): mixv3 + onboard track fp64 closely;");
    println!("mixv1/mixv2 converge later or stall on the harder matrices.");
}
