//! Solve a user-supplied Matrix Market file with the full accelerator
//! evaluation: value plane (iterations, all four platform numerics) and
//! time plane (simulated U280 cycles, GPU analytic model).
//!
//! If no file is given, a demo .mtx is generated on the fly so the
//! example is runnable out of the box.
//!
//! ```bash
//! cargo run --release --example solve_mtx [path/to/matrix.mtx]
//! ```

use std::path::PathBuf;

use callipepla::accel::{evaluate, Accel};
use callipepla::sparse::{mtx, synth};

fn main() -> anyhow::Result<()> {
    let path = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        None => {
            // Ship our own demo input: a banded SPD in .mtx format.
            let demo = std::env::temp_dir().join("callipepla_demo.mtx");
            let a = synth::banded_spd(4_000, 60_000, 1e-4, 99);
            mtx::write_mtx(&a, &demo)?;
            println!("(no input given; wrote demo matrix to {demo:?})");
            demo
        }
    };

    let a = mtx::read_mtx(&path)?;
    println!("loaded {path:?}: n={} nnz={}", a.n, a.nnz());
    if !a.is_symmetric(1e-9) {
        eprintln!("warning: matrix is not symmetric — JPCG may not converge");
    }

    println!(
        "\n{:<12} {:>9} {:>10} {:>14} {:>12} {:>12}",
        "platform", "converged", "iters", "solver time", "GFLOP/s", "GFLOP/J"
    );
    for acc in Accel::ALL {
        let r = evaluate(acc, &a, None);
        if r.failed {
            println!("{:<12} {:>9}", acc.name(), "OOM-FAIL");
            continue;
        }
        println!(
            "{:<12} {:>9} {:>10} {:>12.3e} s {:>12.2} {:>12.3e}",
            acc.name(),
            r.converged,
            r.iters,
            r.solver_seconds,
            r.gflops,
            r.gflops_per_joule
        );
    }
    println!("\n(solver time is the cycle-model estimate for each build — see DESIGN.md §5)");
    Ok(())
}
