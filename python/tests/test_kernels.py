"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes, dtypes, block sizes and value distributions —
the shape sweep is the contract the Rust bucket-padding logic relies on.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    DELAY_LANES,
    axpy,
    dot,
    dot_lanes,
    left_divide,
    spmv,
    update_p,
)
from compile.kernels import ref

# Generous deadlines: interpret-mode pallas is slow under CI load.
SETTINGS = dict(deadline=None, max_examples=20)


def coo(rng, n, nnz, val_dtype):
    vals = rng.standard_normal(nnz).astype(val_dtype)
    col = rng.integers(0, n, nnz).astype(np.int32)
    row = rng.integers(0, n, nnz).astype(np.int32)
    return jnp.array(vals), jnp.array(col), jnp.array(row)


# ------------------------------------------------------------------ spmv
@settings(**SETTINGS)
@given(
    n_pow=st.integers(5, 10),
    nnz_blocks=st.integers(1, 8),
    block_nnz=st.sampled_from([128, 256, 512]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmv_matches_ref(n_pow, nnz_blocks, block_nnz, dtype, seed):
    rng = np.random.default_rng(seed)
    n = 2**n_pow
    nnz = nnz_blocks * block_nnz
    vals, col, row = coo(rng, n, nnz, dtype)
    x = jnp.array(rng.standard_normal(n))
    got = spmv(vals, col, row, x, n, block_nnz=block_nnz)
    want = ref.spmv_ref(vals, col, row, x, n)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_spmv_padding_is_noop():
    """Padded nnz entries (0,0,0.0) must not change y — the Rust bucket
    padding contract."""
    rng = np.random.default_rng(7)
    n, nnz = 128, 512
    vals, col, row = coo(rng, n, nnz, np.float32)
    x = jnp.array(rng.standard_normal(n))
    base = spmv(vals, col, row, x, n, block_nnz=128)
    pad = 256
    valsp = jnp.concatenate([vals, jnp.zeros(pad, vals.dtype)])
    colp = jnp.concatenate([col, jnp.zeros(pad, col.dtype)])
    rowp = jnp.concatenate([row, jnp.zeros(pad, row.dtype)])
    padded = spmv(valsp, colp, rowp, x, n, block_nnz=128)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(padded))


def test_spmv_mixed_v3_casts_before_multiply():
    """Mix-V3 semantics (Fig. 8): f32 value upcast, then f64 multiply.
    The result must equal f64(vals_f32) @ x exactly."""
    rng = np.random.default_rng(3)
    n, nnz = 64, 256
    vals32, col, row = coo(rng, n, nnz, np.float32)
    x = jnp.array(rng.standard_normal(n))
    got = spmv(vals32, col, row, x, n, block_nnz=64)
    want = ref.spmv_ref(vals32.astype(jnp.float64), col, row, x, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spmv_rejects_ragged_block():
    with pytest.raises(ValueError):
        spmv(jnp.zeros(100, jnp.float32), jnp.zeros(100, jnp.int32),
             jnp.zeros(100, jnp.int32), jnp.zeros(64), 64, block_nnz=64)


# ------------------------------------------------------------------- dot
@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 8),
    block=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dot_matches_ref(blocks, block, seed):
    rng = np.random.default_rng(seed)
    n = blocks * block
    a = jnp.array(rng.standard_normal(n))
    b = jnp.array(rng.standard_normal(n))
    np.testing.assert_allclose(dot(a, b, block=block), ref.dot_ref(a, b),
                               rtol=1e-12)


def test_dot_lanes_shape_and_grouping():
    """Phase-I lanes must reproduce the cyclic delay-buffer partial-sum
    grouping: lane j sums elements with index % DELAY_LANES == j."""
    rng = np.random.default_rng(11)
    n = 512
    a = rng.standard_normal(n)
    b = rng.standard_normal(n)
    lanes = np.asarray(dot_lanes(jnp.array(a), jnp.array(b), block=128))
    assert lanes.shape == (DELAY_LANES,)
    prod = a * b
    want = prod.reshape(-1, DELAY_LANES).sum(axis=0)
    # Same grouping => bit-wise comparable up to fp addition order within
    # a lane, which both sides perform in block-major order.
    np.testing.assert_allclose(lanes, want, rtol=1e-12)


def test_dot_zero_vectors():
    z = jnp.zeros(256)
    assert float(dot(z, z, block=64)) == 0.0


# ------------------------------------------------------- axpy and friends
@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 6),
    block=st.sampled_from([64, 256]),
    alpha=st.floats(-1e3, 1e3, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_axpy_matches_ref(blocks, block, alpha, seed):
    rng = np.random.default_rng(seed)
    n = blocks * block
    x = jnp.array(rng.standard_normal(n))
    y = jnp.array(rng.standard_normal(n))
    np.testing.assert_allclose(axpy(alpha, x, y, block=block),
                               ref.axpy_ref(alpha, x, y), rtol=1e-12)


@settings(**SETTINGS)
@given(blocks=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_left_divide_matches_ref(blocks, seed):
    rng = np.random.default_rng(seed)
    n = blocks * 128
    r = jnp.array(rng.standard_normal(n))
    m = jnp.array(np.abs(rng.standard_normal(n)) + 0.5)
    np.testing.assert_allclose(left_divide(r, m, block=128),
                               ref.left_divide_ref(r, m), rtol=1e-15)


@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 6),
    beta=st.floats(-10, 10, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_update_p_matches_ref(blocks, beta, seed):
    rng = np.random.default_rng(seed)
    n = blocks * 128
    z = jnp.array(rng.standard_normal(n))
    p = jnp.array(rng.standard_normal(n))
    np.testing.assert_allclose(update_p(z, beta, p, block=128),
                               ref.update_p_ref(z, beta, p), rtol=1e-12)
