"""L2 correctness: phase graphs vs oracles + a full JPCG driven through
the phase functions converging on a real small SPD system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def laplacian_1d_coo(n, val_dtype=np.float64):
    """Tridiagonal 1-D Poisson matrix: SPD, well-conditioned."""
    rows, cols, vals = [], [], []
    for i in range(n):
        rows.append(i); cols.append(i); vals.append(2.0)
        if i > 0:
            rows.append(i); cols.append(i - 1); vals.append(-1.0)
        if i < n - 1:
            rows.append(i); cols.append(i + 1); vals.append(-1.0)
    return (np.array(vals, val_dtype), np.array(cols, np.int32),
            np.array(rows, np.int32))


def pad_coo(vals, col, row, nnz_pad):
    pad = nnz_pad - len(vals)
    return (np.concatenate([vals, np.zeros(pad, vals.dtype)]),
            np.concatenate([col, np.zeros(pad, col.dtype)]),
            np.concatenate([row, np.zeros(pad, row.dtype)]))


@pytest.fixture(scope="module")
def small_system():
    n, nnz_pad = 256, 1024
    vals, col, row = laplacian_1d_coo(n)
    vals, col, row = pad_coo(vals, col, row, nnz_pad)
    m = np.full(n, 2.0)  # diagonal of A
    b = np.ones(n)
    return dict(n=n, nnz_pad=nnz_pad, vals=jnp.array(vals),
                col=jnp.array(col), row=jnp.array(row),
                m=jnp.array(m), b=jnp.array(b))


def test_phase1_matches_ref(small_system):
    s = small_system
    rng = np.random.default_rng(0)
    p = jnp.array(rng.standard_normal(s["n"]))
    ap, pap = model.phase1(s["vals"], s["col"], s["row"], p, n=s["n"])
    ap_r, pap_r = ref.phase1_ref(s["vals"], s["col"], s["row"], p, s["n"])
    np.testing.assert_allclose(ap, ap_r, rtol=1e-12)
    np.testing.assert_allclose(pap, pap_r, rtol=1e-12)


def test_phase2_matches_ref(small_system):
    s = small_system
    rng = np.random.default_rng(1)
    r = jnp.array(rng.standard_normal(s["n"]))
    ap = jnp.array(rng.standard_normal(s["n"]))
    alpha = jnp.float64(0.37)
    got = model.phase2(r, ap, s["m"], alpha)
    want = ref.phase2_ref(r, ap, s["m"], alpha)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-12)


def test_phase3_matches_ref(small_system):
    s = small_system
    rng = np.random.default_rng(2)
    r, p, x = (jnp.array(rng.standard_normal(s["n"])) for _ in range(3))
    got = model.phase3(r, s["m"], p, x, jnp.float64(0.3), jnp.float64(0.9))
    want = ref.phase3_ref(r, s["m"], p, x, 0.3, 0.9)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-12, atol=1e-14)


def test_full_jpcg_via_phases_converges(small_system):
    """Drive Algorithm 1 exactly as the Rust coordinator will: init phase,
    then phase1/2/3 per iteration with scalars owned by the 'controller'.
    Must converge on the 1-D Poisson system to ||r||^2 < 1e-12."""
    s = small_system
    n = s["n"]
    x = jnp.zeros(n)
    r, z, p, rz, rr = model.init_phase(
        s["vals"], s["col"], s["row"], x, s["b"], s["m"], n=n)
    iters = 0
    for _ in range(4 * n):
        if float(rr) < 1e-12:
            break
        ap, pap = model.phase1(s["vals"], s["col"], s["row"], p, n=n)
        alpha = float(rz) / float(pap)
        r, rz_new, rr = model.phase2(r, ap, s["m"], jnp.float64(alpha))
        beta = float(rz_new) / float(rz)
        p, x = model.phase3(r, s["m"], p, x, jnp.float64(alpha),
                            jnp.float64(beta))
        rz = rz_new
        iters += 1
    assert float(rr) < 1e-12, f"no convergence: rr={float(rr)}"
    # Check the actual solve: A x ≈ b.
    ax = ref.spmv_ref(s["vals"], s["col"], s["row"], x, n)
    np.testing.assert_allclose(ax, s["b"], atol=1e-5)


def test_mixv3_phase1_uses_f32_matrix(small_system):
    """Mix-V3: SpMV result must equal using the f32-rounded matrix in f64
    arithmetic — not the f64 matrix, not f32 arithmetic."""
    s = small_system
    vals32 = s["vals"].astype(jnp.float32)
    rng = np.random.default_rng(5)
    p = jnp.array(rng.standard_normal(s["n"]))
    ap, _ = model.phase1(vals32, s["col"], s["row"], p, n=s["n"])
    want = ref.spmv_ref(vals32.astype(jnp.float64), s["col"], s["row"], p, s["n"])
    np.testing.assert_array_equal(np.asarray(ap), np.asarray(want))


def test_make_jitted_all_phases_trace():
    """Every (phase, scheme) combination must trace/lower without error on
    a tiny bucket — the gate for aot.py."""
    for phase in ["init", "phase1", "phase2", "phase3"]:
        for scheme in ["fp64", "mixv3"]:
            fn, args = model.make_jitted(phase, scheme, 1024, 4096)
            jax.jit(fn).lower(*args)  # must not raise
