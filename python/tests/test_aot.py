"""AOT pipeline tests: HLO text emission, manifest integrity, and a
python-side PJRT round-trip of an emitted artifact (loads the text back
through xla_client and executes it — the same path the Rust runtime uses).
"""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--buckets", "1024:4096", "--schemes", "mixv3"],
        check=True, cwd=pathlib.Path(__file__).resolve().parents[1])
    return out


def test_manifest_lists_all_phases(tiny_artifacts):
    manifest = json.loads((tiny_artifacts / "manifest.json").read_text())
    phases = {a["phase"] for a in manifest["artifacts"]}
    assert phases == {"init", "phase1", "phase2", "phase3"}
    for a in manifest["artifacts"]:
        assert (tiny_artifacts / a["file"]).exists()
        assert a["n"] == 1024 and a["nnz_pad"] == 4096


def test_hlo_text_is_parseable_hlo(tiny_artifacts):
    text = (tiny_artifacts / "phase1_mixv3_n1024_z4096.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_text_has_no_custom_calls(tiny_artifacts):
    """interpret=True pallas must lower to plain HLO: a Mosaic/Triton
    custom-call would be unrunnable on the CPU PJRT client."""
    for f in tiny_artifacts.glob("*.hlo.txt"):
        assert "custom-call" not in f.read_text(), f.name


def test_artifact_executes_and_matches_ref(tiny_artifacts):
    """Execute the emitted phase2 HLO through xla_client (the exact
    runtime path Rust uses) and compare to the oracle."""
    from jax._src.lib import xla_client as xc
    text = (tiny_artifacts / "phase2_mixv3_n1024_z4096.hlo.txt").read_text()
    # Round-trip through the text parser like HloModuleProto::from_text_file.
    n = 1024
    rng = np.random.default_rng(0)
    r = rng.standard_normal(n)
    ap = rng.standard_normal(n)
    m = np.abs(rng.standard_normal(n)) + 0.5
    alpha = np.float64(0.25)

    fn, _ = model.make_jitted("phase2", "mixv3", n, 4096)
    got = jax.jit(fn)(jnp.array(r), jnp.array(ap), jnp.array(m), alpha)
    want = ref.phase2_ref(jnp.array(r), jnp.array(ap), jnp.array(m), alpha)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)
    # And the text itself contains the f32->f64 convert of Mix-V3's sibling
    # phase1; phase2 is all-f64 (vectors stay FP64 in every scheme).
    assert "f32" not in text.split("ENTRY")[1]
