"""AOT lowering: JAX phase graphs -> HLO *text* artifacts for the Rust
PJRT runtime.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Emits one ``<phase>_<scheme>_n<N>_z<NNZ>.hlo.txt`` per (phase, scheme,
bucket) plus ``manifest.json`` describing parameter shapes/dtypes so the
Rust side can validate before feeding literals.
"""
import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

PHASES = ["init", "phase1", "phase2", "phase3"]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple so the Rust
    side can uniformly unwrap tuples)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(phase, scheme, n, nnz_pad):
    fn, args = model.make_jitted(phase, scheme, n, nnz_pad)
    return to_hlo_text(jax.jit(fn).lower(*args))


def artifact_name(phase, scheme, n, nnz_pad):
    return f"{phase}_{scheme}_n{n}_z{nnz_pad}.hlo.txt"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default=None,
                    help="comma list like 1024:16384,4096:131072 (default: model.BUCKETS)")
    ap.add_argument("--schemes", default="fp64,mixv3")
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    if args.buckets:
        buckets = [tuple(int(v) for v in b.split(":")) for b in args.buckets.split(",")]
    else:
        buckets = model.BUCKETS
    schemes = args.schemes.split(",")

    manifest = {"buckets": buckets, "schemes": schemes, "artifacts": []}
    for n, nnz in buckets:
        for scheme in schemes:
            for phase in PHASES:
                name = artifact_name(phase, scheme, n, nnz)
                text = lower_one(phase, scheme, n, nnz)
                (out / name).write_text(text)
                fn, shapes = model.make_jitted(phase, scheme, n, nnz)
                manifest["artifacts"].append({
                    "file": name,
                    "phase": phase,
                    "scheme": scheme,
                    "n": n,
                    "nnz_pad": nnz,
                    "params": [
                        {"shape": list(s.shape), "dtype": str(s.dtype)} for s in shapes
                    ],
                })
                print(f"wrote {name} ({len(text)} chars)")
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
