"""Layer-2 JAX compute graphs for the Callipepla JPCG iteration.

The JPCG main loop (Algorithm 1) is split into the three computation
phases of Fig. 5 — the same split the FPGA uses, because a scalar
dependency (alpha after Phase-1, beta after Phase-2) is a hard barrier on
any substrate.  Each phase is one jit-able function over a fixed
(n, nnz_pad) *bucket*; ``aot.py`` lowers each to HLO text that the Rust
coordinator loads once and executes every iteration.

Scalars (alpha, beta) are *runtime arguments*, mirroring the ``double
alpha`` field of the Type-II computation instruction: the global
controller in Rust computes them and feeds them into the next phase's
executable.

All vectors are FP64 (the paper maintains main-loop vectors in FP64 for
every scheme, §6); the matrix value stream is f32 for Mix-V3 or f64 for
the default scheme.
"""
import functools

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import spmv, dot, axpy, left_divide, update_p

# (n, nnz_pad) buckets compiled by aot.py.  HLO is static-shape, so the
# coordinator pads a problem into the smallest fitting bucket; padded nnz
# are (0, 0, 0.0) no-ops and padded vector lanes hold zeros.
BUCKETS = [
    (1024, 16384),
    (4096, 32768),
    (4096, 131072),
    (16384, 65536),
    (16384, 131072),
    (16384, 524288),
]

SCHEMES = {
    "fp64": jnp.float64,   # default FP64 (Table 1 row 1)
    "mixv3": jnp.float32,  # Mix-V3: f32 matrix, f64 vectors (Table 1 row 4)
}


def phase1(vals, col, row, p, *, n):
    """Phase-1: M1 SpMV (ap = A p) then M2 dot (pap = p . ap).

    VSR: ap streams from M1 straight into the dot and into the ap
    write-back — the controller gets pap and computes alpha = rz / pap.
    """
    ap = spmv(vals, col, row, p, n)
    pap = dot(p, ap)
    return ap, pap


def phase2(r, ap, m, alpha):
    """Phase-2: M4 update-r, M5 left-divide, M6 dot-rz, M8 dot-rr.

    z is computed but deliberately *not* an output: the paper recomputes
    it in Phase-3 rather than spending an off-chip channel on it (§5.3).
    """
    r1 = axpy(-alpha, ap, r)
    z = left_divide(r1, m)
    rz = dot(r1, z)
    rr = dot(r1, r1)
    return r1, rz, rr


def phase3(r, m, p, x, alpha, beta):
    """Phase-3: M4+M5 recompute z, M7 update-p, M3 update-x (old p)."""
    z = left_divide(r, m)
    x1 = axpy(alpha, p, x)
    p1 = update_p(z, beta, p)
    return p1, x1


def init_phase(vals, col, row, x0, b, m, *, n):
    """Lines 1-5 of Algorithm 1: r = b - A x0, z = M^-1 r, p = z,
    rz = r.z, rr = r.r.  The FPGA reuses M1..M8 for this via the rp = -1
    first loop trip (Fig. 4); as an artifact it is its own executable."""
    ax0 = spmv(vals, col, row, x0, n)
    r = b - ax0
    z = left_divide(r, m)
    p = z
    rz = dot(r, z)
    rr = dot(r, r)
    return r, z, p, rz, rr


def make_jitted(phase_name, scheme, n, nnz_pad):
    """Bind a phase to a bucket + precision scheme and return (fn, example
    ShapeDtypeStructs) ready for jax.jit(...).lower(...)."""
    vdt = SCHEMES[scheme]
    f64 = lambda: jax.ShapeDtypeStruct((n,), jnp.float64)
    vals = jax.ShapeDtypeStruct((nnz_pad,), vdt)
    idx = lambda: jax.ShapeDtypeStruct((nnz_pad,), jnp.int32)
    scal = jax.ShapeDtypeStruct((), jnp.float64)
    if phase_name == "phase1":
        fn = functools.partial(phase1, n=n)
        args = (vals, idx(), idx(), f64())
    elif phase_name == "phase2":
        fn = phase2
        args = (f64(), f64(), f64(), scal)
    elif phase_name == "phase3":
        fn = phase3
        args = (f64(), f64(), f64(), f64(), scal, scal)
    elif phase_name == "init":
        fn = functools.partial(init_phase, n=n)
        args = (vals, idx(), idx(), f64(), f64(), f64())
    else:
        raise ValueError(phase_name)
    return fn, args
