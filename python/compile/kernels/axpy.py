"""Streaming vector-update Pallas kernels (modules M3/M4/M5/M7).

Each is a pure element-wise II=1 stream: one element in, one element out
per cycle on the FPGA; on TPU a blocked VPU map.  They share one generic
blocked elementwise builder so the BlockSpec schedule (the HBM<->VMEM
burst pattern) is identical across M3/M4/M5/M7 — matching the paper's
observation that all vector modules run at the same streaming rate
(processing-rate matching, §4.2).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _blocked_call(kernel, n, block, n_vec_inputs, scalar=False):
    """Blocked elementwise pallas_call: n_vec_inputs vectors (+ optional
    broadcast scalar) -> one vector."""
    block = min(block, n)
    if n % block != 0:
        raise ValueError(f"n={n} not a multiple of block={block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    in_specs = [spec] * n_vec_inputs
    if scalar:
        in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=in_specs,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )


def _axpy_kernel(x_ref, y_ref, a_ref, o_ref):
    o_ref[...] = y_ref[...] + a_ref[0] * x_ref[...]


def axpy(alpha, x, y, block=DEFAULT_BLOCK):
    """o = y + alpha*x  (M3 'update x' with +alpha, M4 'update r' with
    -alpha).  ``alpha`` is the Type-II instruction's ``double alpha``
    field; it enters the kernel as a (1,)-shaped SMEM-style operand so the
    lowered HLO takes it as a runtime parameter, not a compile-time
    constant — the accelerator must serve *arbitrary* problems (§2.3.1).
    """
    a = jnp.asarray(alpha, jnp.float64).reshape(1)
    return _blocked_call(_axpy_kernel, x.shape[0], block, 2, scalar=True)(x, y, a)


def _left_divide_kernel(r_ref, m_ref, o_ref):
    o_ref[...] = r_ref[...] / m_ref[...]


def left_divide(r, m, block=DEFAULT_BLOCK):
    """z = M^{-1} r, Jacobi: element-wise divide by the diagonal (M5)."""
    return _blocked_call(_left_divide_kernel, r.shape[0], block, 2)(r, m)


def _update_p_kernel(z_ref, p_ref, b_ref, o_ref):
    o_ref[...] = z_ref[...] + b_ref[0] * p_ref[...]


def update_p(z, beta, p, block=DEFAULT_BLOCK):
    """p' = z + beta*p (M7)."""
    b = jnp.asarray(beta, jnp.float64).reshape(1)
    return _blocked_call(_update_p_kernel, z.shape[0], block, 2, scalar=True)(z, p, b)
