"""Cyclic-delay-buffer dot product Pallas kernel (modules M2/M6/M8).

Mirrors Callipepla's two-phase dot product (paper footnote 1):

  Phase I  — II=1 pipeline: each incoming element pair is multiplied and
             accumulated into one lane of a cyclic delay buffer of length
             ``DELAY_LANES`` (the FPGA uses L == FP-add latency so the
             accumulator never sees a read-after-write hazard).
  Phase II — the L lanes are reduced with a slower (II=5 on the FPGA)
             tail whose cost is independent of the vector length.

On TPU the delay buffer becomes a VMEM vector of ``DELAY_LANES`` partial
sums; the lane-parallel accumulate is exactly what the VPU wants.  The
kernel returns the *lanes*, and :func:`dot` applies the Phase-II reduce —
keeping the two phases separate lets the Rust cycle model charge them
independently (II=1 * len/L  vs  5 * L).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# FPGA value is 8 f64 adders deep; keep the same so partial-sum grouping
# (and thus rounding) matches the hardware design the paper measured.
DELAY_LANES = 8

DEFAULT_BLOCK = 4096


def _dot_kernel(a_ref, b_ref, lanes_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        lanes_ref[...] = jnp.zeros_like(lanes_ref)

    a = a_ref[...].astype(jnp.float64)
    b = b_ref[...].astype(jnp.float64)
    prod = a * b
    # Cyclic assignment of element i to lane i % L, vectorised as a
    # (block/L, L) fold — identical partial-sum grouping to the FPGA's
    # cyclic delay buffer.
    lanes_ref[...] += prod.reshape(-1, DELAY_LANES).sum(axis=0)


def dot_lanes(a, b, block=DEFAULT_BLOCK):
    """Phase I only: return the DELAY_LANES partial sums."""
    n = a.shape[0]
    block = min(block, n)
    if n % block != 0 or block % DELAY_LANES != 0:
        raise ValueError(f"n={n} must tile into blocks of {block} divisible by {DELAY_LANES}")
    call = pl.pallas_call(
        _dot_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((DELAY_LANES,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((DELAY_LANES,), jnp.float64),
        interpret=True,
    )
    return call(a, b)


def dot(a, b, block=DEFAULT_BLOCK):
    """Full dot product: Phase I lanes + Phase II tail reduce."""
    return dot_lanes(a, b, block).sum()
