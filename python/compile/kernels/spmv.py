"""Mixed-precision streamed SpMV Pallas kernel (module M1, paper §6).

TPU re-think of the Callipepla / Serpens SpMV (DESIGN.md §Hardware-
Adaptation): the FPGA design streams 64-bit packed non-zeros from 16 HBM
channels into 8 PEs each, holds the input vector in a BRAM "X memory" and
accumulates the output in a URAM "Y memory".  On TPU the analogue is:

  * the nnz stream is tiled over the Pallas *grid* with a ``BlockSpec`` —
    one grid step == one burst of ``block_nnz`` non-zeros arriving from HBM;
  * the input vector x lives whole in VMEM (the X-memory analogue; its
    BlockSpec index map pins it to block 0 for every grid step);
  * the output y lives whole in VMEM and is revisited by every grid step
    (the Y-memory accumulate port), with the scatter-accumulate expressed
    as a dense ``.at[].add`` per burst.

Mix-V3 (the scheme Callipepla ships): ``vals`` arrives as f32 and is cast
to f64 *before* the multiply, x and y stay f64 — exactly the cast placement
of Fig. 8 step (1).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_NNZ = 2048


def _spmv_kernel(vals_ref, col_ref, row_ref, x_ref, y_ref, *, n):
    """One grid step: consume one burst of non-zeros, accumulate into y."""
    step = pl.program_id(0)

    # First burst initialises the Y memory (the FPGA design zeroes URAM
    # while the first burst is in flight).
    @pl.when(step == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = vals_ref[...]
    col = col_ref[...]
    row = row_ref[...]
    x = x_ref[...]

    # Fig. 8 pipeline: (1) cast f32 value to f64, (2) gather x[col],
    # (3) multiply, (4) accumulate at row.
    contrib = vals.astype(y_ref.dtype) * x[col]
    y_ref[...] += jnp.zeros(n, dtype=y_ref.dtype).at[row].add(contrib)


def spmv_pallas_call(n, nnz_pad, val_dtype, block_nnz=DEFAULT_BLOCK_NNZ):
    """Build the pallas_call for a given (n, nnz_pad) bucket.

    ``val_dtype`` selects the precision scheme for the stored matrix:
    jnp.float32 == Mix-V3, jnp.float64 == default FP64 (Table 1).
    """
    block_nnz = min(block_nnz, nnz_pad)
    if nnz_pad % block_nnz != 0:
        raise ValueError(f"nnz_pad={nnz_pad} not a multiple of block_nnz={block_nnz}")
    grid = (nnz_pad // block_nnz,)
    whole = lambda step: (0,)  # pin x / y blocks to VMEM for every step
    burst = lambda step: (step,)
    return pl.pallas_call(
        functools.partial(_spmv_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_nnz,), burst),  # vals: streamed from HBM
            pl.BlockSpec((block_nnz,), burst),  # col
            pl.BlockSpec((block_nnz,), burst),  # row
            pl.BlockSpec((n,), whole),          # x: VMEM-resident
        ],
        out_specs=pl.BlockSpec((n,), whole),    # y: VMEM accumulator
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )


def spmv(vals, col, row, x, n, block_nnz=DEFAULT_BLOCK_NNZ):
    """y = A @ x over padded COO streams; convenience entry point."""
    call = spmv_pallas_call(n, vals.shape[0], vals.dtype, block_nnz)
    return call(vals, col, row, x)
