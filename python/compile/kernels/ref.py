"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel is checked
against the function of the same name here (pytest + hypothesis sweeps in
``python/tests/``). They intentionally use the most direct jnp formulation.
"""
import jax.numpy as jnp


def spmv_ref(vals, col, row, x, n):
    """y = A @ x with A given as padded COO streams.

    ``vals`` may be f32 (Mix-V3: cast up before multiply, paper §6) or f64.
    Padded entries carry ``vals == 0`` and point at (row 0, col 0), so they
    contribute nothing.
    """
    contrib = vals.astype(x.dtype) * x[col]
    return jnp.zeros(n, dtype=x.dtype).at[row].add(contrib)


def dot_ref(a, b):
    """FP64 dot product (modules M2/M6/M8)."""
    return jnp.dot(a.astype(jnp.float64), b.astype(jnp.float64))


def axpy_ref(alpha, x, y):
    """y + alpha * x (modules M3/M4)."""
    return y + alpha * x


def left_divide_ref(r, m):
    """z = M^{-1} r for the Jacobi preconditioner: element-wise divide
    by the diagonal (module M5)."""
    return r / m


def update_p_ref(z, beta, p):
    """p = z + beta * p (module M7)."""
    return z + beta * p


def phase1_ref(vals, col, row, p, n):
    """Phase-1 of Fig. 5: M1 (SpMV) then M2 (dot alpha)."""
    ap = spmv_ref(vals, col, row, p, n)
    pap = dot_ref(p, ap)
    return ap, pap


def phase2_ref(r, ap, m, alpha):
    """Phase-2 of Fig. 5: M4 (update r), M5 (left divide), M6 (dot rz),
    M8 (dot rr). z is *not* returned: the paper recomputes it in Phase-3
    to save an off-chip channel (§5.3)."""
    r1 = axpy_ref(-alpha, ap, r)
    z = left_divide_ref(r1, m)
    rz = dot_ref(r1, z)
    rr = dot_ref(r1, r1)
    return r1, rz, rr


def phase3_ref(r, m, p, x, alpha, beta):
    """Phase-3 of Fig. 5: M4+M5 recompute z from r, then M7 (update p)
    and M3 (update x, using the *old* p)."""
    z = left_divide_ref(r, m)
    x1 = axpy_ref(alpha, p, x)
    p1 = update_p_ref(z, beta, p)
    return p1, x1
