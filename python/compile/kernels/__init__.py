"""Layer-1 Pallas kernels for the Callipepla JPCG stack.

Every kernel is authored with ``interpret=True`` so it lowers to plain HLO
ops executable on the CPU PJRT client (real-TPU Mosaic custom-calls cannot
run there; see DESIGN.md §Hardware-Adaptation).
"""
from .spmv import spmv, spmv_pallas_call
from .dot import dot, dot_lanes, DELAY_LANES
from .axpy import axpy, left_divide, update_p

__all__ = [
    "spmv",
    "spmv_pallas_call",
    "dot",
    "dot_lanes",
    "DELAY_LANES",
    "axpy",
    "left_divide",
    "update_p",
]
